//! Minimal JSON: enough to read `manifest.json`, `sfu_luts.json` and
//! `golden/*.json` (objects / arrays / strings / f64 numbers / bools /
//! null) and to write experiment outputs. No external deps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.as_ref().display()))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (getting {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        Ok(self.num()? as usize)
    }

    /// Strict unsigned integer: rejects negatives, fractions, and values
    /// at or above 2^53. Beyond 2^53, f64 cannot represent every integer,
    /// so e.g. the text `9007199254740993` (2^53+1) already parsed to
    /// 2^53 — a config knob silently rounding is worse than an error.
    pub fn u64_exact(&self) -> Result<u64> {
        let n = self.num()?;
        if n < 0.0 || n.fract() != 0.0 || n >= (1u64 << 53) as f64 {
            bail!("not an exactly-representable unsigned integer: {n}");
        }
        Ok(n as u64)
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.arr()?.iter().map(|v| Ok(v.num()? as f32)).collect()
    }

    /// Decode an f32 stored as its IEEE-754 bit pattern (the convention
    /// of [`f32_bits`]): strict — the number must be an exact integer in
    /// `[0, 2^32)`, so a corrupted or hand-edited bits field errors
    /// instead of silently rounding onto some other float.
    pub fn f32_from_bits(&self) -> Result<f32> {
        let n = self.num()?;
        if !(n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64) {
            bail!("not an IEEE-754 f32 bit pattern: {n}");
        }
        Ok(f32::from_bits(n as u32))
    }

    /// Decode an array written by [`f32_bits_arr`].
    pub fn f32_bits_vec(&self) -> Result<Vec<f32>> {
        self.arr()?.iter().map(|v| v.f32_from_bits()).collect()
    }

    pub fn i64_vec(&self) -> Result<Vec<i64>> {
        self.arr()?.iter().map(|v| Ok(v.num()? as i64)).collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    // ---- writer ------------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                c => {
                    // Re-consume multi-byte UTF-8 sequences as raw bytes.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        // Find the full UTF-8 char starting at i-1.
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

/// Convenience constructors for writing experiment outputs.
impl Json {
    pub fn obj_from(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

/// Encode an f32 as its IEEE-754 bit pattern (a JSON integer in
/// `[0, 2^32)`, exactly representable in f64). The single convention for
/// bit-exact float round-trips in JSON artifacts — calibration-table
/// ranges ([`crate::quant::CalibTable`]) and model-artifact manifest
/// floats share this implementation, and [`Json::f32_from_bits`] /
/// [`Json::f32_bits_vec`] are the strict inverses.
pub fn f32_bits(v: f32) -> Json {
    Json::Num(v.to_bits() as f64)
}

/// Encode a slice of f32s as an array of IEEE-754 bit patterns.
pub fn f32_bits_arr(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| f32_bits(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":true,"c":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn typed_vectors() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.i64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn u64_exact_strictness() {
        assert_eq!(Json::parse("42").unwrap().u64_exact().unwrap(), 42);
        assert_eq!(
            Json::parse("9007199254740991").unwrap().u64_exact().unwrap(),
            (1u64 << 53) - 1
        );
        assert!(Json::parse("-1").unwrap().u64_exact().is_err());
        assert!(Json::parse("1.5").unwrap().u64_exact().is_err());
        // 2^53+1 aliases to 2^53 during f64 parse: must error, not round.
        assert!(Json::parse("9007199254740993").unwrap().u64_exact().is_err());
    }

    #[test]
    fn f32_bits_round_trip_is_exact() {
        // Values with no short decimal form survive bit-for-bit, through
        // an actual serialize -> parse cycle.
        let vals = [0.1f32, 1e-12, f32::MIN_POSITIVE, 3.14159265, -0.0, 1234.5678e-3];
        let j = Json::parse(&f32_bits_arr(&vals).dump()).unwrap();
        let back = j.f32_bits_vec().unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Strictness: negatives, fractions, and out-of-range u32 reject.
        assert!(Json::parse("-1").unwrap().f32_from_bits().is_err());
        assert!(Json::parse("1.5").unwrap().f32_from_bits().is_err());
        assert!(Json::parse("4294967296").unwrap().f32_from_bits().is_err());
        assert_eq!(Json::parse("4294967295").unwrap().f32_from_bits().unwrap().to_bits(), u32::MAX);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""héllo A""#).unwrap();
        assert_eq!(j.str().unwrap(), "héllo A");
    }
}
