//! In-crate utilities replacing external dependencies (offline build: only
//! the vendored `xla` closure is available — DESIGN.md).

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Pcg;
