//! In-crate utilities replacing external dependencies (offline build: only
//! the vendored `xla` closure is available — DESIGN.md).

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Pcg;

/// Write a file, creating parent directories as needed — the one
/// implementation behind every artifact writer (calibration tables,
/// model artifacts, engine reports).
pub fn write_creating_dirs(
    path: impl AsRef<std::path::Path>,
    bytes: &[u8],
) -> anyhow::Result<()> {
    use anyhow::Context as _;
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}
