//! Accuracy evaluation subsystem: deterministic eval sets, the f32
//! reference oracle, and the accuracy/size frontier sweep.
//!
//! The bench/perfcheck pattern measured *speed*; this subsystem is its
//! accuracy twin. An [`EvalSet`] is a seeded synthetic image stream
//! (the same generator the calibration and chaos harnesses use), so the
//! "golden labels" are hermetic: the label of item `i` is whatever the
//! f32 reference forward — dense weights, dynamic per-item scan, no
//! calibration — says it is. Every served variant (quantized weights,
//! INT8 activations, static calibration, lazy artifacts) is then scored
//! *against that oracle*: top-1/top-5 agreement, per-class logit MSE,
//! and max relative logit error ([`report::ModelEval`]).
//!
//! `mamba-x eval` drives the variants through the real serving engine
//! (admission, batching, epoch machinery — not a direct forward call)
//! and emits `EVAL_hotpath.json`; `mamba-x evalcheck` compares it
//! against committed `EVAL_baseline.json` floors in CI exactly like
//! `perfcheck` ([`report::check_eval`]). The INT8-activation serving
//! path (`"activations": "i8"`) landed gated on this subsystem: its
//! drift budget is a committed ceiling here, not a hope.

pub mod report;

pub use report::{
    argmax, check_eval, top_k, BoundKind, EvalCheck, EvalGate, EvalReport, FrontierPoint,
    FrontierSweep, ModelEval, EVAL_BASELINE_FORMAT, EVAL_BASELINE_VERSION, EVAL_FORMAT,
    EVAL_VERSION,
};

use anyhow::{bail, Result};

use crate::config::MambaXConfig;
use crate::quant::{WeightQuantOpts, WeightQuantPlan};
use crate::sim::sfu::SfuTables;
use crate::vision::VimWeights;

/// A deterministic seeded evaluation set: `samples` flattened images of
/// `input_len` elements each. Item `i` is
/// [`crate::runtime::native::synthetic_image`]`(seed, i, input_len)` —
/// the same stream the quantization search calibrates on (under its own
/// seed), so identical `(seed, samples, input_len)` always reproduces
/// the set bit-for-bit, on any host.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalSet {
    pub seed: u64,
    pub input_len: usize,
    pub items: Vec<Vec<f32>>,
}

impl EvalSet {
    pub fn synthetic(seed: u64, samples: usize, input_len: usize) -> Result<EvalSet> {
        if samples == 0 {
            bail!("eval set needs at least one sample");
        }
        if input_len == 0 {
            bail!("eval set needs a nonzero input length");
        }
        let items = (0..samples as u64)
            .map(|id| crate::runtime::native::synthetic_image(seed, id, input_len))
            .collect();
        Ok(EvalSet { seed, input_len, items })
    }

    /// Borrowed view of the items, the shape the forward pass takes.
    pub fn refs(&self) -> Vec<&[f32]> {
        self.items.iter().map(|v| v.as_slice()).collect()
    }
}

/// The f32 reference oracle: densify the weights (INT8 storage is
/// decoded back to f32 — for dense weights this is an exact copy) and
/// run the dynamic-scan batched forward. This is the accuracy
/// ground-truth every variant is scored against; for a dense f32
/// variant served without calibration it is bitwise-identical to what
/// the engine serves, which is why the committed f32 floors sit at
/// exactly 1.0.
pub fn oracle_logits(weights: &VimWeights, set: &EvalSet) -> Result<Vec<Vec<f32>>> {
    let want = weights.cfg.input_len();
    if set.input_len != want {
        bail!(
            "eval set has {}-element images but model {} expects {want}",
            set.input_len,
            weights.cfg.model.name
        );
    }
    let dense = weights.dequantized();
    Ok(dense.forward_batch(&SfuTables::fitted(), &MambaXConfig::default(), &set.refs()))
}

/// Sweep the weight-quantization accuracy/size frontier: for each clip
/// percentile in `opts.percentiles`, quantize *every* eligible tensor
/// at that percentile (no per-site search — the point is to chart the
/// uniform-candidate curve the search picks from) and score the result
/// against the f32 oracle. Input weights must be dense f32 (pass the
/// variant's dequantized source).
pub fn weight_quant_frontier(
    weights: &VimWeights,
    set: &EvalSet,
    opts: &WeightQuantOpts,
) -> Result<Vec<FrontierPoint>> {
    let dense = weights.dequantized();
    let oracle = oracle_logits(&dense, set)?;
    let names = dense.weight_quant_candidates();
    let tables = SfuTables::fitted();
    let scan_cfg = MambaXConfig::default();
    let mut points = Vec::with_capacity(opts.percentiles.len());
    for &p in &opts.percentiles {
        let plan = WeightQuantPlan::all_at_percentile(&names, p);
        let mut q = dense.clone();
        q.apply_weight_quant(&plan)?;
        let got = q.forward_batch(&tables, &scan_cfg, &set.refs());
        let m = ModelEval::compute(&format!("frontier@{p}"), "f32", &oracle, &got)?;
        let (f32_eq, stored) = q.weight_bytes();
        points.push(FrontierPoint {
            percentile: p,
            weight_bytes_f32: f32_eq as u64,
            weight_bytes_stored: stored as u64,
            top1_agreement: m.top1_agreement,
            max_rel_err: m.max_rel_err,
        });
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VimModel;
    use crate::vision::ForwardConfig;

    fn tiny_weights(seed: u64) -> VimWeights {
        let cfg = ForwardConfig {
            model: VimModel {
                name: "eval-tiny",
                d_model: 16,
                n_blocks: 2,
                d_state: 4,
                expand: 2,
                conv_k: 4,
                patch: 4,
            },
            img: 8,
            in_ch: 1,
            n_classes: 6,
        };
        VimWeights::init(&cfg, seed)
    }

    #[test]
    fn eval_sets_are_deterministic_and_validated() {
        let a = EvalSet::synthetic(7, 4, 64).unwrap();
        let b = EvalSet::synthetic(7, 4, 64).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.items.len(), 4);
        assert!(a.items.iter().all(|i| i.len() == 64));
        let c = EvalSet::synthetic(8, 4, 64).unwrap();
        assert_ne!(a, c, "different seeds must differ");
        assert!(EvalSet::synthetic(7, 0, 64).is_err());
        assert!(EvalSet::synthetic(7, 4, 0).is_err());
    }

    #[test]
    fn oracle_agrees_with_itself_exactly() {
        let w = tiny_weights(11);
        let set = EvalSet::synthetic(3, 3, w.cfg.input_len()).unwrap();
        let a = oracle_logits(&w, &set).unwrap();
        let b = oracle_logits(&w, &set).unwrap();
        assert_eq!(a, b);
        let m = ModelEval::compute("self", "f32", &a, &b).unwrap();
        assert_eq!(m.top1_agreement, 1.0);
        assert_eq!(m.max_rel_err, 0.0);
        // Shape mismatch is a typed error, not a panic.
        let bad = EvalSet::synthetic(3, 2, 7).unwrap();
        assert!(oracle_logits(&w, &bad).is_err());
    }

    #[test]
    fn frontier_sweeps_every_candidate_and_shrinks_storage() {
        let w = tiny_weights(5);
        let set = EvalSet::synthetic(9, 3, w.cfg.input_len()).unwrap();
        let opts = WeightQuantOpts::default();
        let points = weight_quant_frontier(&w, &set, &opts).unwrap();
        assert_eq!(points.len(), opts.percentiles.len());
        for (pt, &p) in points.iter().zip(&opts.percentiles) {
            assert_eq!(pt.percentile, p);
            assert!(
                pt.weight_bytes_stored < pt.weight_bytes_f32,
                "uniform INT8 at p={p} must shrink storage"
            );
            assert!(pt.max_rel_err.is_finite());
            assert!((0.0..=1.0).contains(&pt.top1_agreement));
        }
        let again = weight_quant_frontier(&w, &set, &opts).unwrap();
        assert_eq!(points, again, "frontier sweep is deterministic");
    }
}
