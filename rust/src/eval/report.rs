//! Accuracy reports and the `evalcheck` gate.
//!
//! [`EvalReport`] is the machine-readable output of `mamba-x eval`
//! (`EVAL_hotpath.json`): per served model variant, agreement and drift
//! metrics against the f32 reference oracle, plus the optional
//! weight-quantization accuracy/size frontier. Everything in the file is
//! a deterministic function of (engine config, eval seed, sample count)
//! — no wall-clock fields — so two runs with identical inputs produce
//! *byte-identical* JSON (the CI determinism gate `cmp`s the files).
//!
//! [`check_eval`] is the accuracy twin of the perf gate
//! ([`crate::util::bench::check_speedups`]): a committed
//! `EVAL_baseline.json` carries **floors** for agreement metrics
//! (current must reach `floor - tolerance`) and **ceilings** for drift
//! metrics (current must stay under `ceiling + tolerance`). The
//! tolerance is *absolute* — agreements live in [0, 1], so a relative
//! margin would be meaningless at 1.0. A metric the baseline names but
//! the current report lacks is a FAILURE: silently dropping a gated
//! model variant must not pass CI. Foreign and future baseline files
//! are refused typed, mirroring [`crate::quant::CalibTable`].

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Format tag of `EVAL_hotpath.json` (the eval report).
pub const EVAL_FORMAT: &str = "mamba-x-eval";

/// Current eval report version; readers reject anything else.
pub const EVAL_VERSION: u32 = 1;

/// Format tag of `EVAL_baseline.json` (the committed gate floors).
pub const EVAL_BASELINE_FORMAT: &str = "mamba-x-eval-baseline";

/// Current baseline version; `check_eval` refuses future versions.
pub const EVAL_BASELINE_VERSION: u32 = 1;

/// First index of the row maximum (ties break to the lowest class
/// index, deterministically).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate().skip(1) {
        if v.total_cmp(&row[best]).is_gt() {
            best = i;
        }
    }
    best
}

/// Indices of the `k` largest values, ordered by (value desc, index
/// asc) — a total order, so identical logits always rank identically.
pub fn top_k(row: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Accuracy metrics of one served model variant against the f32 oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelEval {
    /// Registry name the engine served this variant under.
    pub name: String,
    /// Activation mode the variant ran with (`"f32"` or `"i8"`).
    pub activations: String,
    /// Eval items measured.
    pub samples: usize,
    /// Fraction of items whose argmax matches the oracle's.
    pub top1_agreement: f64,
    /// Fraction of items whose top-5 (or top-`n_classes` for tiny heads)
    /// contains the oracle's top-1 class.
    pub top5_agreement: f64,
    /// Per-class mean squared logit error over items.
    pub logit_mse: Vec<f64>,
    /// Mean of `logit_mse` across classes.
    pub mean_logit_mse: f64,
    /// Max over items of `||got - oracle||_2 / ||oracle||_2` (the same
    /// shape as the weight-quant search's relative logit error).
    pub max_rel_err: f64,
    /// f32-equivalent weight bytes of the served backend.
    pub weight_bytes_f32: u64,
    /// Actually stored weight bytes (smaller once INT8 storage is in
    /// play; equal for dense f32 variants).
    pub weight_bytes_stored: u64,
}

impl ModelEval {
    /// Compute the metrics for one variant: `got[i]` is the engine's
    /// logits row for eval item `i`, `oracle[i]` the f32 reference's.
    /// Fails on shape mismatches and on a zero-norm oracle row that the
    /// candidate did not reproduce exactly (the relative error would be
    /// unbounded — synthetic and real heads never emit all-zero logits).
    pub fn compute(
        name: &str,
        activations: &str,
        oracle: &[Vec<f32>],
        got: &[Vec<f32>],
    ) -> Result<ModelEval> {
        if oracle.is_empty() {
            bail!("eval of model {name:?} has no items");
        }
        if oracle.len() != got.len() {
            bail!(
                "eval of model {name:?}: {} oracle rows vs {} served rows",
                oracle.len(),
                got.len()
            );
        }
        let n_classes = oracle[0].len();
        let k = n_classes.min(5);
        let mut top1 = 0usize;
        let mut top5 = 0usize;
        let mut sq_err = vec![0f64; n_classes];
        let mut max_rel_err = 0f64;
        for (item, (o, g)) in oracle.iter().zip(got).enumerate() {
            if o.len() != n_classes || g.len() != n_classes {
                bail!(
                    "eval of model {name:?} item {item}: logits width {} vs {} \
                     (oracle has {n_classes} classes)",
                    o.len(),
                    g.len()
                );
            }
            let want = argmax(o);
            if argmax(g) == want {
                top1 += 1;
            }
            if top_k(g, k).contains(&want) {
                top5 += 1;
            }
            let mut num = 0f64;
            let mut den = 0f64;
            for (c, (ov, gv)) in o.iter().zip(g).enumerate() {
                let d = *gv as f64 - *ov as f64;
                sq_err[c] += d * d;
                num += d * d;
                den += *ov as f64 * *ov as f64;
            }
            let rel = if den == 0.0 {
                if num == 0.0 {
                    0.0
                } else {
                    bail!(
                        "eval of model {name:?} item {item}: oracle logits have zero \
                         norm but the served logits differ (relative error unbounded)"
                    );
                }
            } else {
                (num / den).sqrt()
            };
            if rel > max_rel_err {
                max_rel_err = rel;
            }
        }
        let n = oracle.len();
        let logit_mse: Vec<f64> = sq_err.into_iter().map(|s| s / n as f64).collect();
        let mean_logit_mse = logit_mse.iter().sum::<f64>() / n_classes as f64;
        Ok(ModelEval {
            name: name.to_string(),
            activations: activations.to_string(),
            samples: n,
            top1_agreement: top1 as f64 / n as f64,
            top5_agreement: top5 as f64 / n as f64,
            logit_mse,
            mean_logit_mse,
            max_rel_err,
            weight_bytes_f32: 0,
            weight_bytes_stored: 0,
        })
    }

    /// The gate-facing `"model:metric"` pairs of this variant.
    pub fn metric_pairs(&self) -> Vec<(String, f64)> {
        vec![
            (format!("{}:top1_agreement", self.name), self.top1_agreement),
            (format!("{}:top5_agreement", self.name), self.top5_agreement),
            (format!("{}:mean_logit_mse", self.name), self.mean_logit_mse),
            (format!("{}:max_rel_err", self.name), self.max_rel_err),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj_from(vec![
            ("name", Json::Str(self.name.clone())),
            ("activations", Json::Str(self.activations.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("top1_agreement", Json::Num(self.top1_agreement)),
            ("top5_agreement", Json::Num(self.top5_agreement)),
            ("logit_mse", Json::Arr(self.logit_mse.iter().map(|&v| Json::Num(v)).collect())),
            ("mean_logit_mse", Json::Num(self.mean_logit_mse)),
            ("max_rel_err", Json::Num(self.max_rel_err)),
            ("weight_bytes_f32", Json::Num(self.weight_bytes_f32 as f64)),
            ("weight_bytes_stored", Json::Num(self.weight_bytes_stored as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelEval> {
        let logit_mse = j
            .get("logit_mse")?
            .arr()?
            .iter()
            .map(|v| v.num())
            .collect::<Result<Vec<f64>>>()?;
        Ok(ModelEval {
            name: j.get("name")?.str()?.to_string(),
            activations: j.get("activations")?.str()?.to_string(),
            samples: j.get("samples")?.usize()?,
            top1_agreement: j.get("top1_agreement")?.num()?,
            top5_agreement: j.get("top5_agreement")?.num()?,
            logit_mse,
            mean_logit_mse: j.get("mean_logit_mse")?.num()?,
            max_rel_err: j.get("max_rel_err")?.num()?,
            weight_bytes_f32: j.get("weight_bytes_f32")?.u64_exact()?,
            weight_bytes_stored: j.get("weight_bytes_stored")?.u64_exact()?,
        })
    }
}

/// One point of a weight-quantization accuracy/size frontier: every
/// eligible tensor quantized at one clip percentile, measured against
/// the same f32 oracle as the serving metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    pub percentile: f32,
    pub weight_bytes_f32: u64,
    pub weight_bytes_stored: u64,
    pub top1_agreement: f64,
    pub max_rel_err: f64,
}

impl FrontierPoint {
    pub fn to_json(&self) -> Json {
        Json::obj_from(vec![
            ("percentile", Json::Num(self.percentile as f64)),
            ("weight_bytes_f32", Json::Num(self.weight_bytes_f32 as f64)),
            ("weight_bytes_stored", Json::Num(self.weight_bytes_stored as f64)),
            ("top1_agreement", Json::Num(self.top1_agreement)),
            ("max_rel_err", Json::Num(self.max_rel_err)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FrontierPoint> {
        Ok(FrontierPoint {
            percentile: j.get("percentile")?.num()? as f32,
            weight_bytes_f32: j.get("weight_bytes_f32")?.u64_exact()?,
            weight_bytes_stored: j.get("weight_bytes_stored")?.u64_exact()?,
            top1_agreement: j.get("top1_agreement")?.num()?,
            max_rel_err: j.get("max_rel_err")?.num()?,
        })
    }
}

/// The frontier sweep of one quantize-spec variant (one point per
/// candidate percentile, in candidate order).
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierSweep {
    pub model: String,
    pub points: Vec<FrontierPoint>,
}

/// The full `mamba-x eval` artifact (`EVAL_hotpath.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalReport {
    /// Seed of the deterministic eval image stream.
    pub seed: u64,
    /// Items per model variant.
    pub samples: usize,
    /// Engine config the models were served through (path or label).
    pub config: String,
    pub models: Vec<ModelEval>,
    /// Accuracy/size frontiers of quantize-spec variants (empty when no
    /// variant carries a `quantize` spec).
    pub frontier: Vec<FrontierSweep>,
}

impl EvalReport {
    /// Flattened `"model:metric"` map the gate consumes.
    pub fn metric_pairs(&self) -> Vec<(String, f64)> {
        self.models.iter().flat_map(|m| m.metric_pairs()).collect()
    }

    pub fn to_json(&self) -> Json {
        let frontier = self
            .frontier
            .iter()
            .map(|f| {
                Json::obj_from(vec![
                    ("model", Json::Str(f.model.clone())),
                    ("points", Json::Arr(f.points.iter().map(FrontierPoint::to_json).collect())),
                ])
            })
            .collect();
        Json::obj_from(vec![
            ("format", Json::Str(EVAL_FORMAT.to_string())),
            ("version", Json::Num(EVAL_VERSION as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("samples", Json::Num(self.samples as f64)),
            ("config", Json::Str(self.config.clone())),
            ("models", Json::Arr(self.models.iter().map(ModelEval::to_json).collect())),
            ("frontier", Json::Arr(frontier)),
        ])
    }

    /// Parse a report, refusing foreign formats and non-current versions
    /// typed (same policy as every other versioned artifact here).
    pub fn from_json(j: &Json) -> Result<EvalReport> {
        let format = j.get("format")?.str()?;
        if format != EVAL_FORMAT {
            bail!("not an eval report (format {format:?}, expected {EVAL_FORMAT:?})");
        }
        let version = j.get("version")?.num()? as u32;
        if version != EVAL_VERSION {
            bail!(
                "unsupported eval report version {version} (this build reads \
                 v{EVAL_VERSION}; re-run `mamba-x eval`)"
            );
        }
        let models = j
            .get("models")?
            .arr()?
            .iter()
            .map(ModelEval::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut frontier = Vec::new();
        for f in j.get("frontier")?.arr()? {
            frontier.push(FrontierSweep {
                model: f.get("model")?.str()?.to_string(),
                points: f
                    .get("points")?
                    .arr()?
                    .iter()
                    .map(FrontierPoint::from_json)
                    .collect::<Result<Vec<_>>>()?,
            });
        }
        Ok(EvalReport {
            seed: j.get("seed")?.u64_exact()?,
            samples: j.get("samples")?.usize()?,
            config: j.get("config")?.str()?.to_string(),
            models,
            frontier,
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::util::write_creating_dirs(path, self.to_json().dump().as_bytes())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<EvalReport> {
        let path = path.as_ref();
        Self::from_json(&Json::load(path)?)
            .with_context(|| format!("loading eval report {}", path.display()))
    }
}

/// Whether a gate bound is a floor (agreement must reach it) or a
/// ceiling (drift must stay under it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundKind {
    Floor,
    Ceiling,
}

/// One gate comparison: the committed bound vs the current value.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalCheck {
    /// `"model:metric"` key.
    pub name: String,
    pub kind: BoundKind,
    /// The committed floor or ceiling.
    pub bound: f64,
    /// The current report's value; `None` when the metric is missing
    /// (always a failure).
    pub current: Option<f64>,
    pub pass: bool,
}

/// Outcome of [`check_eval`]: per-metric verdicts under one absolute
/// tolerance.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalGate {
    pub tolerance: f64,
    pub checks: Vec<EvalCheck>,
}

impl EvalGate {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.pass)
    }

    pub fn failed(&self) -> Vec<&EvalCheck> {
        self.checks.iter().filter(|c| !c.pass).collect()
    }
}

/// Compare a current eval report against a committed baseline.
///
/// The baseline shape is
/// `{"format", "version", "tolerance", "floors": {"model:metric": f},
///   "ceilings": {"model:metric": c}}` — floors fail when
/// `current < floor - tolerance`, ceilings when
/// `current > ceiling + tolerance`, and a missing metric always fails.
/// `tolerance_override` (the `--tolerance` flag) replaces the baseline's
/// committed tolerance. Foreign/future files on either side are refused
/// typed, never partially evaluated.
pub fn check_eval(
    current: &Json,
    baseline: &Json,
    tolerance_override: Option<f64>,
) -> Result<EvalGate> {
    let report = EvalReport::from_json(current).context("current eval report")?;
    let format = baseline.get("format").context("eval baseline")?.str()?;
    if format != EVAL_BASELINE_FORMAT {
        bail!("not an eval baseline (format {format:?}, expected {EVAL_BASELINE_FORMAT:?})");
    }
    let version = baseline.get("version")?.num()? as u32;
    if version > EVAL_BASELINE_VERSION {
        bail!(
            "eval baseline version {version} is newer than this build understands \
             (v{EVAL_BASELINE_VERSION}); update the binary or recommit the baseline"
        );
    }
    let tolerance = match tolerance_override {
        Some(t) => t,
        None => match baseline.opt("tolerance") {
            Some(t) => t.num()?,
            None => 0.0,
        },
    };
    if !(tolerance.is_finite() && tolerance >= 0.0) {
        bail!("eval tolerance must be finite and >= 0, got {tolerance}");
    }
    let current_map: std::collections::BTreeMap<String, f64> =
        report.metric_pairs().into_iter().collect();
    let mut checks = Vec::new();
    for (kind, key) in [(BoundKind::Floor, "floors"), (BoundKind::Ceiling, "ceilings")] {
        let Some(bounds) = baseline.opt(key) else { continue };
        for (name, bound) in bounds.obj()? {
            let bound = bound.num().with_context(|| format!("baseline {key} entry {name:?}"))?;
            let current_v = current_map.get(name).copied();
            let pass = match kind {
                BoundKind::Floor => current_v.is_some_and(|c| c >= bound - tolerance),
                BoundKind::Ceiling => current_v.is_some_and(|c| c <= bound + tolerance),
            };
            checks.push(EvalCheck { name: name.clone(), kind, bound, current: current_v, pass });
        }
    }
    if checks.is_empty() {
        bail!("eval baseline contains no floors or ceilings — nothing would be gated");
    }
    Ok(EvalGate { tolerance, checks })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(models: Vec<ModelEval>) -> EvalReport {
        EvalReport {
            seed: 7,
            samples: models.first().map_or(0, |m| m.samples),
            config: "test".to_string(),
            models,
            frontier: Vec::new(),
        }
    }

    fn eval_of(name: &str, top1: f64, rel: f64) -> ModelEval {
        ModelEval {
            name: name.to_string(),
            activations: "f32".to_string(),
            samples: 4,
            top1_agreement: top1,
            top5_agreement: 1.0,
            logit_mse: vec![0.0, 0.0],
            mean_logit_mse: 0.0,
            max_rel_err: rel,
            weight_bytes_f32: 100,
            weight_bytes_stored: 100,
        }
    }

    fn baseline(tol: f64, floors: Vec<(&str, f64)>, ceilings: Vec<(&str, f64)>) -> Json {
        let fl = floors.into_iter().map(|(n, v)| (n, Json::Num(v))).collect();
        let ce = ceilings.into_iter().map(|(n, v)| (n, Json::Num(v))).collect();
        Json::obj_from(vec![
            ("format", Json::Str(EVAL_BASELINE_FORMAT.to_string())),
            ("version", Json::Num(EVAL_BASELINE_VERSION as f64)),
            ("tolerance", Json::Num(tol)),
            ("floors", Json::obj_from(fl)),
            ("ceilings", Json::obj_from(ce)),
        ])
    }

    #[test]
    fn argmax_and_top_k_are_deterministic_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1, "first max wins");
        assert_eq!(top_k(&[1.0, 3.0, 3.0, 2.0], 3), vec![1, 2, 3]);
        assert_eq!(top_k(&[0.5], 5), vec![0], "k larger than the row");
    }

    #[test]
    fn identical_logits_score_perfect_agreement() {
        let rows = vec![vec![0.1f32, 0.9, -0.4], vec![2.0, -1.0, 0.5]];
        let m = ModelEval::compute("m", "f32", &rows, &rows).unwrap();
        assert_eq!(m.top1_agreement, 1.0);
        assert_eq!(m.top5_agreement, 1.0);
        assert_eq!(m.max_rel_err, 0.0);
        assert_eq!(m.logit_mse, vec![0.0; 3]);
        assert_eq!(m.mean_logit_mse, 0.0);
    }

    #[test]
    fn disagreement_and_drift_are_measured() {
        let oracle = vec![vec![1.0f32, 0.0], vec![0.0, 1.0]];
        // Item 0 flips the argmax; item 1 agrees but drifts.
        let got = vec![vec![0.0f32, 1.0], vec![0.0, 0.5]];
        let m = ModelEval::compute("m", "i8", &oracle, &got).unwrap();
        assert_eq!(m.top1_agreement, 0.5);
        // Two classes: top-2 always contains the oracle class.
        assert_eq!(m.top5_agreement, 1.0);
        assert!(m.max_rel_err > 0.0);
        // Item 0 contributes 1.0 to both classes, item 1 contributes 0.25
        // to class 1: mse = [0.5, 0.625].
        assert_eq!(m.logit_mse, vec![0.5, 0.625]);
        let e = ModelEval::compute("m", "f32", &oracle, &got[..1].to_vec()).unwrap_err();
        assert!(e.to_string().contains("oracle rows"), "{e}");
    }

    #[test]
    fn report_json_roundtrip_is_exact_and_refuses_foreign_or_future() {
        let mut m = eval_of("a@f32", 1.0, 0.0);
        m.logit_mse = vec![0.125, 0.25];
        let report = EvalReport {
            seed: 9,
            samples: 4,
            config: "engine.json".to_string(),
            models: vec![m],
            frontier: vec![FrontierSweep {
                model: "a@f32".to_string(),
                points: vec![FrontierPoint {
                    percentile: 0.999,
                    weight_bytes_f32: 400,
                    weight_bytes_stored: 120,
                    top1_agreement: 0.75,
                    max_rel_err: 0.125,
                }],
            }],
        };
        let dump = report.to_json().dump();
        let back = EvalReport::from_json(&Json::parse(&dump).unwrap()).unwrap();
        assert_eq!(back, report);
        // Determinism: dump -> parse -> dump is byte-stable.
        assert_eq!(back.to_json().dump(), dump);

        let future = dump.replace("\"version\":1", "\"version\":99");
        let e = EvalReport::from_json(&Json::parse(&future).unwrap()).unwrap_err();
        assert!(e.to_string().contains("version 99"), "{e}");
        let foreign = dump.replace(EVAL_FORMAT, "mamba-x-bench");
        assert!(EvalReport::from_json(&Json::parse(&foreign).unwrap()).is_err());
    }

    #[test]
    fn gate_floor_and_ceiling_semantics() {
        let report = report_with(vec![eval_of("m@f32", 0.95, 0.08)]);
        let current = report.to_json();
        // Floor met within tolerance, ceiling met exactly.
        let gate = check_eval(
            &current,
            &baseline(0.05, vec![("m@f32:top1_agreement", 1.0)], vec![("m@f32:max_rel_err", 0.08)]),
            None,
        )
        .unwrap();
        assert!(gate.passed(), "{:?}", gate.failed());
        // Floor missed beyond tolerance.
        let gate = check_eval(
            &current,
            &baseline(0.01, vec![("m@f32:top1_agreement", 1.0)], vec![]),
            None,
        )
        .unwrap();
        assert!(!gate.passed());
        // Ceiling exceeded beyond tolerance; override rescues it.
        let b = baseline(0.001, vec![], vec![("m@f32:max_rel_err", 0.05)]);
        assert!(!check_eval(&current, &b, None).unwrap().passed());
        assert!(check_eval(&current, &b, Some(0.5)).unwrap().passed());
    }

    #[test]
    fn gate_missing_metric_fails_and_bad_baselines_are_refused() {
        let report = report_with(vec![eval_of("m@f32", 1.0, 0.0)]);
        let current = report.to_json();
        let gate = check_eval(
            &current,
            &baseline(0.1, vec![("gone@i8:top1_agreement", 0.5)], vec![]),
            None,
        )
        .unwrap();
        assert!(!gate.passed(), "missing metric must fail");
        assert_eq!(gate.failed()[0].current, None);

        let mut foreign = baseline(0.1, vec![("m@f32:top1_agreement", 1.0)], vec![]);
        if let Json::Obj(o) = &mut foreign {
            o.insert("format".to_string(), Json::Str("mamba-x-bench".to_string()));
        }
        assert!(check_eval(&current, &foreign, None).is_err());

        let mut future = baseline(0.1, vec![("m@f32:top1_agreement", 1.0)], vec![]);
        if let Json::Obj(o) = &mut future {
            o.insert("version".to_string(), Json::Num(99.0));
        }
        let e = check_eval(&current, &future, None).unwrap_err();
        assert!(e.to_string().contains("newer"), "{e}");

        let empty = Json::obj_from(vec![
            ("format", Json::Str(EVAL_BASELINE_FORMAT.to_string())),
            ("version", Json::Num(1.0)),
        ]);
        assert!(check_eval(&current, &empty, None).is_err(), "empty baseline gates nothing");

        let bad_tol = baseline(-0.5, vec![("m@f32:top1_agreement", 1.0)], vec![]);
        assert!(check_eval(&current, &bad_tol, None).is_err());
    }
}
