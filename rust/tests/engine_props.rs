//! Engine API v1 properties (the acceptance gate for the multi-model
//! redesign):
//!
//! * one process hosting TWO registered variants of the same model — a
//!   dynamic-scale one and a statically calibrated one — returns
//!   per-request logits *bit-identical* to direct single-model inference
//!   on the matching variant, under interleaved clients and shared
//!   workers;
//! * an over-SLO burst is refused with typed `Rejected { Shed }` errors
//!   while in-SLO traffic on the same engine completes, and an accepted
//!   request is never shed later;
//! * unknown model names are refused typed (`Rejected { UnknownModel }`)
//!   and counted in the final report.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;
use mamba_x::config::{MambaXConfig, VimModel};
use mamba_x::coordinator::{
    BatchPolicy, EngineBuilder, EngineError, Priority, RejectReason, Request,
};
use mamba_x::quant::CalibTable;
use mamba_x::runtime::{
    native::synthetic_image, InferenceBackend, ModelSource, ModelSpec, NativeBackend, Tensor,
};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::Pcg;
use mamba_x::vision::{ForwardConfig, VimWeights};

/// Small-but-real model (same as `serving_props.rs`): every datapath
/// stage of the micro model, an order of magnitude fewer multiplies.
fn prop_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

/// Offline-calibrate the prop model exactly as `mamba-x calibrate` does,
/// over a handful of synthetic samples.
fn prop_calib(cfg: &ForwardConfig, weight_seed: u64, image_seed: u64) -> Arc<CalibTable> {
    let weights = VimWeights::init(cfg, weight_seed);
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let imgs: Vec<Vec<f32>> =
        (0..6).map(|id| synthetic_image(image_seed, id, cfg.input_len())).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    Arc::new(weights.calibrate(&tables, &scan, &refs, 1.0).expect("calibration succeeds"))
}

/// ACCEPTANCE: two variants (`prop@dynamic`, `prop@calib`) served from
/// one engine are bitwise identical to direct per-variant inference, for
/// randomized pool geometries and interleaved clients.
#[test]
fn prop_two_variants_bitwise_equal_direct() {
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let weight_seed = 42u64;
    let calib = prop_calib(&cfg, weight_seed, 7);
    let mut rng = Pcg::new(0xE6E1);
    for case in 0..12u64 {
        let workers = rng.usize_in(1, 3);
        let max_batch = rng.usize_in(1, 6);
        let max_wait_us = rng.usize_in(0, 1000) as u64;
        let per_client = rng.usize_in(2, 5);
        let image_seed = 100 + case;

        let source = ModelSource::RandomInit { config: cfg.clone(), seed: weight_seed };
        let (engine, join) = EngineBuilder::new()
            .workers(workers)
            .policy(BatchPolicy { max_batch, max_wait_us })
            .queue_depth(64)
            .register(ModelSpec::new(
                "prop@dynamic",
                NativeBackend::factory(source.clone(), None, None).unwrap(),
            ))
            .unwrap()
            .register(ModelSpec::new(
                "prop@calib",
                NativeBackend::factory(source, Some(Arc::clone(&calib)), None).unwrap(),
            ))
            .unwrap()
            .build()
            .unwrap();

        // Two clients, each alternating between the variants, so batches
        // of both models interleave on the shared workers.
        let mut clients = Vec::new();
        for c in 0..2usize {
            let eng = engine.clone();
            let shape = cfg.input_shape();
            clients.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_client {
                    let id = (c * per_client + i) as u64;
                    let model =
                        if (c + i) % 2 == 0 { "prop@dynamic" } else { "prop@calib" };
                    let data = synthetic_image(image_seed, id, shape.iter().product());
                    let req =
                        Request::new(model, id, Tensor::new(shape.clone(), data).unwrap());
                    let resp = eng.infer(req).expect("queue depth 64 never rejects here");
                    assert_eq!(resp.model, model, "response names the serving variant");
                    got.push((model, resp.id, resp.logits));
                }
                got
            }));
        }
        let mut responses = Vec::new();
        for c in clients {
            responses.extend(c.join().unwrap());
        }
        drop(engine);
        let report = join.join().expect("engine joins cleanly");
        assert_eq!(responses.len(), 2 * per_client, "case {case}");
        assert_eq!(report.completed(), responses.len(), "case {case}");
        assert_eq!(report.merged().rejected(), 0, "case {case}");

        // Direct per-variant oracles: bit-identical logits per request.
        let mut dynamic = NativeBackend::new(&cfg, weight_seed);
        let mut calibrated = NativeBackend::new(&cfg, weight_seed)
            .with_calib(Arc::clone(&calib))
            .expect("table fits the prop model");
        for (model, id, logits) in responses {
            let img =
                Tensor::new(cfg.input_shape(), synthetic_image(image_seed, id, n_elems)).unwrap();
            let want = match model {
                "prop@dynamic" => dynamic.infer(&img).unwrap(),
                _ => calibrated.infer(&img).unwrap(),
            };
            assert_eq!(
                logits, want,
                "case {case} req {id} via {model}: served logits diverge \
                 (workers={workers} max_batch={max_batch} wait={max_wait_us})"
            );
        }
    }
}

/// Backend that blocks every inference until the shared gate opens —
/// makes queue occupancy deterministic for admission tests.
struct Gated {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl InferenceBackend for Gated {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn infer(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(vec![image.data[0]])
    }
}

fn gated_spec(name: &str, gate: &Arc<(Mutex<bool>, Condvar)>) -> ModelSpec {
    let gate = Arc::clone(gate);
    ModelSpec::new(
        name,
        Arc::new(move |_w| {
            Ok(Box::new(Gated { gate: Arc::clone(&gate) }) as Box<dyn InferenceBackend>)
        }),
    )
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (lock, cv) = &**gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

/// ACCEPTANCE: with a seeded service-time estimate and a deterministic
/// backlog (backend gated shut), a request whose deadline is already
/// below the projected wait is refused `Rejected { Shed }`, while in-SLO
/// traffic on the same engine is admitted — and every admitted request
/// completes once the gate opens (accepted is never shed later).
#[test]
fn over_slo_burst_sheds_typed_while_in_slo_completes() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let hint_us = 10_000u64;
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
        .queue_depth(64)
        .register(gated_spec("gated", &gate).service_hint_us(hint_us))
        .unwrap()
        .build()
        .unwrap();

    let img = || Tensor::new(vec![1], vec![5.0]).unwrap();
    // Build a backlog the single blocked worker cannot drain: at most one
    // request leaves the queue (max_batch 1), so >= 3 stay pending.
    let mut accepted = Vec::new();
    for id in 0..4u64 {
        let req = Request::new("gated", id, img()).priority(Priority::High);
        accepted.push(engine.submit(req).expect("no deadline, depth 64: admitted"));
    }
    // In-SLO request: deadline far above any projection (<= 4 * hint).
    let in_slo_req =
        Request::new("gated", 100, img()).priority(Priority::High).deadline_us(40 * hint_us);
    let in_slo = engine.submit(in_slo_req).expect("in-SLO request is admitted");
    // Over-SLO burst: projected wait >= 3 * hint dwarfs a 1us deadline.
    let err = engine
        .submit(Request::new("gated", 200, img()).priority(Priority::High).deadline_us(1))
        .expect_err("over-SLO request is shed at admission");
    assert_eq!(err.reject_reason(), Some(RejectReason::Shed));
    assert!(
        matches!(
            err,
            EngineError::Rejected { ref model, reason: RejectReason::Shed, .. } if model == "gated"
        ),
        "typed shed: {err}"
    );
    assert!(err.to_string().contains("projected wait"), "evidence in detail: {err}");

    open_gate(&gate);
    for w in accepted {
        assert_eq!(w.wait().expect("accepted requests complete").logits, vec![5.0]);
    }
    assert_eq!(in_slo.wait().expect("accepted in-SLO request completes").id, 100);
    drop(engine);
    let report = join.join().unwrap();
    let m = report.model("gated").expect("hosted model reported");
    assert_eq!(m.metrics.count(), 5, "4 backlog + 1 in-SLO completed");
    assert_eq!(m.metrics.rejected_shed, 1);
    assert_eq!(m.metrics.rejected_full, 0);
}

/// Priority shedding order, deterministically: with the backend gated
/// shut and the backlog at the Low threshold, a Low request is shed
/// typed while a High request at the same instant is admitted (and then
/// completes).
#[test]
fn low_priority_sheds_before_high_at_same_backlog() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
        .queue_depth(4) // Low sheds at 2, Normal at 3, High at 4
        .register(gated_spec("gated", &gate))
        .unwrap()
        .build()
        .unwrap();
    let img = || Tensor::new(vec![1], vec![1.0]).unwrap();
    let mut accepted = Vec::new();
    for id in 0..3u64 {
        accepted.push(
            engine
                .submit(Request::new("gated", id, img()).priority(Priority::High))
                .expect("below depth 4"),
        );
    }
    // Backlog is now 2 or 3 pending (the blocked worker holds at most
    // one): at or above Low's threshold of 2, below High's of 4.
    let err = engine
        .submit(Request::new("gated", 10, img()).priority(Priority::Low))
        .expect_err("low priority sheds under backlog");
    assert_eq!(err.reject_reason(), Some(RejectReason::Shed));
    accepted.push(
        engine
            .submit(Request::new("gated", 11, img()).priority(Priority::High))
            .expect("high priority still admitted at the same backlog"),
    );
    open_gate(&gate);
    for w in accepted {
        w.wait().expect("accepted requests complete");
    }
    drop(engine);
    let report = join.join().unwrap();
    assert_eq!(report.model("gated").unwrap().metrics.rejected_shed, 1);
    assert_eq!(report.completed(), 4);
}

/// Unknown model names are refused typed, counted, and never enqueued.
#[test]
fn unknown_model_rejected_typed_and_counted() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    open_gate(&gate); // backend never blocks in this test
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 2, max_wait_us: 100 })
        .register(gated_spec("prop@dynamic", &gate))
        .unwrap()
        .build()
        .unwrap();
    let err = engine
        .infer(Request::new("prop@nope", 1, Tensor::new(vec![1], vec![0.0]).unwrap()))
        .unwrap_err();
    assert_eq!(err.reject_reason(), Some(RejectReason::UnknownModel));
    assert!(err.to_string().contains("prop@dynamic"), "detail lists hosted models: {err}");
    let ok = engine
        .infer(Request::new("prop@dynamic", 2, Tensor::new(vec![1], vec![3.0]).unwrap()))
        .unwrap();
    assert_eq!(ok.logits, vec![3.0]);
    drop(engine);
    let report = join.join().unwrap();
    assert_eq!(report.rejected_unknown_model, 1);
    assert_eq!(report.completed(), 1);
}
