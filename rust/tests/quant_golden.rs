//! Bit-exactness contract: the rust INT8 SPE datapath, quantizer rounding,
//! pow2 scale approximation and SFU LUT evaluation must reproduce the
//! python-generated golden vectors in `artifacts/golden/` EXACTLY.
//!
//! Requires `make artifacts` to have run; tests are skipped (with a
//! loud message) if the goldens are missing.

use mamba_x::quant::{pow2_round, pow2_shift, quantize, spe_scan_int};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::Json;

fn golden(name: &str) -> Option<Json> {
    let path = format!("artifacts/golden/{name}");
    if !std::path::Path::new(&path).exists() {
        eprintln!("SKIP: {path} missing — run `make artifacts` first");
        return None;
    }
    Some(Json::load(&path).expect("golden parse"))
}

#[test]
fn spe_scan_matches_python_exactly() {
    let Some(j) = golden("spe_scan.json") else { return };
    let cases = j.get("cases").unwrap().arr().unwrap();
    assert!(!cases.is_empty());
    for (ci, c) in cases.iter().enumerate() {
        let l = c.get("L").unwrap().usize().unwrap();
        let h = c.get("H").unwrap().usize().unwrap();
        let n = c.get("N").unwrap().usize().unwrap();
        let p = c.get("p").unwrap().i64_vec().unwrap();
        let q = c.get("q").unwrap().i64_vec().unwrap();
        let shift: Vec<i32> = c
            .get("shift")
            .unwrap()
            .i64_vec()
            .unwrap()
            .iter()
            .map(|&x| x as i32)
            .collect();
        let want = c.get("out").unwrap().i64_vec().unwrap();
        let got = spe_scan_int(&p, &q, &shift, l, h, n);
        assert_eq!(got, want, "case {ci} (L={l},H={h},N={n})");
    }
}

#[test]
fn quantize_rounding_matches_python_exactly() {
    let Some(j) = golden("quantize.json") else { return };
    let xs = j.get("x").unwrap().f32_vec().unwrap();
    let s = j.get("scale").unwrap().num().unwrap() as f32;
    let want = j.get("q").unwrap().f32_vec().unwrap();
    for (i, (&x, &w)) in xs.iter().zip(want.iter()).enumerate() {
        assert_eq!(quantize(x, s) as f32, w, "x[{i}]={x}");
    }
}

#[test]
fn pow2_matches_python_exactly() {
    let Some(j) = golden("pow2.json") else { return };
    let s = j.get("s").unwrap().f32_vec().unwrap();
    let rounded = j.get("rounded").unwrap().f32_vec().unwrap();
    let shift = j.get("shift").unwrap().i64_vec().unwrap();
    for i in 0..s.len() {
        assert_eq!(pow2_round(s[i]), rounded[i], "s[{i}]={}", s[i]);
        assert_eq!(pow2_shift(s[i]) as i64, shift[i], "s[{i}]={}", s[i]);
    }
}

#[test]
fn sfu_lut_eval_matches_python_exactly() {
    let Some(j) = golden("lut_eval.json") else { return };
    let tables = SfuTables::load("artifacts/sfu_luts.json").expect("luts");
    for (name, case) in j.obj().unwrap() {
        let xs = case.get("x").unwrap().f32_vec().unwrap();
        let want = case.get("y").unwrap().f32_vec().unwrap();
        let t = match name.as_str() {
            "silu" => &tables.silu,
            "exp" => &tables.exp,
            "softplus" => &tables.softplus,
            other => panic!("unknown function {other}"),
        };
        for (i, (&x, &w)) in xs.iter().zip(want.iter()).enumerate() {
            let got = t.eval(x);
            assert_eq!(got, w, "{name} x[{i}]={x}: got {got} want {w}");
        }
    }
}

#[test]
fn sfu_lut_is_accurate_in_range() {
    // Beyond bit-exactness: the fitted tables approximate the real
    // functions well where the profile says inputs live (Fig 19's left
    // end-state).
    if !std::path::Path::new("artifacts/sfu_luts.json").exists() {
        eprintln!("SKIP: artifacts/sfu_luts.json missing");
        return;
    }
    let tables = SfuTables::load("artifacts/sfu_luts.json").unwrap();
    for (t, f) in [
        (&tables.exp, mamba_x::vision::SfuFunc::Exp),
        (&tables.silu, mamba_x::vision::SfuFunc::Silu),
        (&tables.softplus, mamba_x::vision::SfuFunc::Softplus),
    ] {
        let lo = t.bps[0];
        let hi = *t.bps.last().unwrap();
        let mut max_err = 0.0f32;
        let mut scale = 1.0f32;
        for i in 0..2000 {
            let x = lo + (hi - lo) * i as f32 / 1999.0;
            let exact = mamba_x::sim::sfu::LutTable::exact(f, x);
            max_err = max_err.max((t.eval(x) - exact).abs());
            scale = scale.max(exact.abs());
        }
        assert!(max_err / scale < 0.02, "{}: rel err {}", t.name, max_err / scale);
    }
}
