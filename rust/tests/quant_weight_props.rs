//! Properties of the hybrid weight-quantization path: INT8 artifact
//! tensors (v2), the quantized GEMM kernels, and the per-site precision
//! search (hand-rolled harness: proptest is unavailable offline; `Pcg`
//! provides deterministic shrink-free random cases).
//!
//! The contract under test:
//!
//! * `matmul_q8` is *bitwise identical* to dequantize-then-`matmul`
//!   (same accumulation schedule by construction), and `matmul_i8`
//!   matches an exact f32-over-integer-codes oracle with the identical
//!   epilogue, over random shapes;
//! * a quantized-weight artifact (v2, mixed f32/i8 tensors) saves,
//!   reopens, and serves bitwise what the in-memory quantized weights
//!   compute — which itself equals the dequantized f32 oracle;
//! * full INT8 on `micro_s` lands at <= 30% of the f32 blob;
//! * the committed v1 golden fixture migrates: quantize -> save writes a
//!   v2 artifact whose forward is bitwise the quantized in-memory model;
//! * corrupt dtype/scale records are rejected with the *typed*
//!   [`ArtifactError`] variant naming the failure (forbidden i8 on a
//!   sensitive tensor, manifest/weights dtype drift, non-positive /
//!   non-finite / drifted scales, header-vs-manifest version mismatch);
//! * the precision search is deterministic and only ever quantizes
//!   eligible tensors.

use std::path::PathBuf;

use mamba_x::config::MambaXConfig;
use mamba_x::quant::{
    quantize_rows_i8, quantize_tensor, QuantTensor, TensorDtype, WeightQuantOpts, WeightQuantPlan,
};
use mamba_x::runtime::{
    fnv1a64, ArtifactError, ArtifactStore, InferenceBackend, ModelSource, NativeBackend,
    Provenance, VimArtifact, WeightQuantSpec, ARTIFACT_VERSION,
};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::Pcg;
use mamba_x::vision::{
    matmul, matmul_i8, matmul_q8, quantizable_tensor, ForwardConfig, VimWeights, WeightMat,
};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/artifact_v1.bin")
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mamba_x_quant_props_{}_{tag}", std::process::id()))
}

fn rand_image(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..len).map(|_| rng.f32_in(-1.0, 1.0)).collect()
}

fn prov(detail: &str) -> Provenance {
    Provenance { tool: "quant_weight_props".to_string(), detail: detail.to_string() }
}

/// `micro_s` weights with every eligible tensor forced to INT8 at plain
/// absmax — the deterministic "maximally quantized" model the artifact
/// and corruption tests build on (no search in the loop).
fn fully_quantized_micro_s(seed: u64) -> (ForwardConfig, VimWeights) {
    let cfg = ForwardConfig::micro_s();
    let mut weights = VimWeights::init(&cfg, seed);
    let plan = WeightQuantPlan::all_at_absmax(&weights.weight_quant_candidates());
    assert!(!plan.sites.is_empty(), "micro_s must expose quantizable sites");
    weights.apply_weight_quant(&plan).unwrap();
    (cfg, weights)
}

// ---------------------------------------------------------------------------
// Kernel <-> oracle equivalence
// ---------------------------------------------------------------------------

/// PROPERTY: over random shapes, `matmul_q8(x, q, s)` is bitwise
/// `matmul(x, dequant(q, s))`, and `matmul_i8` is bitwise the same
/// product computed over the integer codes in f32 with an identical
/// `(sx * sw) * acc + bias` epilogue. The f32-over-codes oracle is
/// exact because every partial sum stays below 2^24 (k <= 96 here,
/// k * 127 * 127 < 2^24 holds up to k = 1040).
#[test]
fn prop_quantized_gemms_match_their_oracles_bitwise() {
    let mut rng = Pcg::new(0x0817_5CA1E);
    for case in 0..10u64 {
        let m = rng.usize_in(1, 33); // crosses the MR tile edge
        let k = rng.usize_in(1, 96);
        let n = rng.usize_in(1, 70); // crosses the NR tile edge
        let x: Vec<f32> = (0..m * k).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.f32_in(-0.5, 0.5)).collect();
        let b = (case % 2 == 0).then_some(bias.as_slice());
        let tag = format!("case {case}: {m}x{k}x{n} bias={}", b.is_some());

        let qt = quantize_tensor(&w, k, n, 1.0);
        let oracle = matmul(&x, &qt.dequant(), b, m, k, n);
        let got = matmul_q8(&x, &qt.q, &qt.scales, b, m, k, n);
        assert_eq!(got.len(), oracle.len(), "{tag}");
        for (i, (g, o)) in got.iter().zip(&oracle).enumerate() {
            assert_eq!(g.to_bits(), o.to_bits(), "{tag}: matmul_q8 element {i}");
        }

        let (qx, xscales) = quantize_rows_i8(&x, m, k);
        let got = matmul_i8(&qx, &xscales, &qt.q, &qt.scales, b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32; // exact: integer-valued partial sums < 2^24
                for kk in 0..k {
                    acc += qx[i * k + kk] as f32 * qt.q[kk * n + j] as f32;
                }
                let v = (xscales[i] * qt.scales[j]) * acc;
                let want = match b {
                    Some(bb) => v + bb[j],
                    None => v,
                };
                assert_eq!(
                    got[i * n + j].to_bits(),
                    want.to_bits(),
                    "{tag}: matmul_i8 element ({i},{j})"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized artifact v2: round trip, serving, size
// ---------------------------------------------------------------------------

/// A mixed f32/i8 artifact saves -> opens -> serves bitwise what the
/// in-memory quantized weights compute, which in turn equals the
/// dequantized f32 oracle; every tensor view survives unchanged, the
/// manifest records i8 only on eligible tensors, and full INT8 puts
/// `micro_s` at <= 30% of its f32 blob.
#[test]
fn quantized_artifact_round_trips_and_serves_bitwise() {
    let (cfg, weights) = fully_quantized_micro_s(33);
    let (f32_eq, stored) = weights.weight_bytes();
    assert!(
        (stored as f64) <= 0.30 * f32_eq as f64,
        "full INT8 micro_s stores {stored} of {f32_eq} f32-equivalent bytes \
         ({:.1}%), expected <= 30%",
        100.0 * stored as f64 / f32_eq as f64
    );

    let artifact = VimArtifact::from_weights(weights.clone(), None, prov("v2")).unwrap();
    assert_eq!(artifact.manifest.version, ARTIFACT_VERSION);
    let mut i8_tensors = 0usize;
    for t in &artifact.manifest.tensors {
        match t.dtype {
            TensorDtype::I8 => {
                assert!(quantizable_tensor(&t.name), "{}: i8 on a sensitive tensor", t.name);
                i8_tensors += 1;
            }
            TensorDtype::F32 => {}
        }
    }
    assert!(i8_tensors > 0, "full plan must produce i8 tensor records");
    let meta_stored: u64 = artifact.manifest.tensors.iter().map(|t| t.stored_bytes()).sum();
    assert_eq!(meta_stored, stored as u64, "manifest byte accounting");

    let path = temp_path("v2_roundtrip.mxa");
    ArtifactStore::save(&path, &artifact).unwrap();
    let summary = ArtifactStore::inspect(&path).unwrap();
    assert_eq!(summary.manifest, artifact.manifest);
    assert_eq!(summary.weight_bytes, stored as u64);
    assert_eq!(summary.params * 4, f32_eq as u64);

    let loaded = ArtifactStore::open(&path).unwrap();
    assert_eq!(loaded.manifest, artifact.manifest);
    for ((name, a), (_, b)) in weights.named_tensors().iter().zip(loaded.weights.named_tensors()) {
        assert_eq!(*a, b, "tensor {name} drifted through the v2 blob");
    }

    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let dequant = weights.dequantized();
    let mut backend = NativeBackend::from_source(&ModelSource::Artifact(path.clone())).unwrap();
    for s in 0..3u64 {
        let img = rand_image(500 + s, cfg.input_len());
        let want = weights.forward_batch(&tables, &scan, &[img.as_slice()]);
        let oracle = dequant.forward_batch(&tables, &scan, &[img.as_slice()]);
        assert_eq!(want, oracle, "image {s}: quantized forward != dequantized f32 oracle");
        let t = mamba_x::runtime::Tensor::new(cfg.input_shape(), img).unwrap();
        assert_eq!(backend.infer(&t).unwrap(), want[0], "image {s}: artifact serving diverged");
    }
    let reported = backend.weight_bytes().expect("native backend reports weight bytes");
    assert_eq!(reported, (f32_eq, stored));
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// v1 -> v2 migration
// ---------------------------------------------------------------------------

/// The typed migration path: open the committed v1 fixture (pure f32),
/// run the precision search over it, and save — the result is a v2
/// artifact that reopens and forwards bitwise as the quantized
/// in-memory model, with the v1 calibration table carried along.
#[test]
fn golden_v1_migrates_to_quantized_v2_bitwise() {
    let v1 = ArtifactStore::open(golden_path()).unwrap();
    assert_eq!(v1.manifest.version, 1);
    assert!(v1.manifest.tensors.iter().all(|t| t.dtype == TensorDtype::F32));
    let cfg = v1.manifest.forward_config().unwrap();

    let spec = WeightQuantSpec { samples: 2, seed: 11 };
    let quantized = NativeBackend::quantize_weights(&v1.weights, &spec).unwrap();
    let migrated =
        VimArtifact::from_weights(quantized.clone(), v1.calib.clone(), prov("migrate")).unwrap();
    assert_eq!(migrated.manifest.version, ARTIFACT_VERSION);
    assert_eq!(migrated.calib, v1.calib, "migration must carry the calibration table");

    let path = temp_path("migrated_v2.mxa");
    ArtifactStore::save(&path, &migrated).unwrap();
    let back = ArtifactStore::open(&path).unwrap();
    assert_eq!(back.manifest, migrated.manifest);
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let img = rand_image(7, cfg.input_len());
    assert_eq!(
        back.weights.forward_batch(&tables, &scan, &[img.as_slice()]),
        quantized.forward_batch(&tables, &scan, &[img.as_slice()]),
        "migrated artifact forward != in-memory quantized forward"
    );

    // Quantizing twice is refused with a message naming the state.
    let err = NativeBackend::quantize_weights(&quantized, &spec);
    match err {
        Ok(_) => panic!("double quantization must be refused"),
        Err(e) => assert!(
            e.to_string().contains("already quantized"),
            "unexpected double-quantize error: {e}"
        ),
    }
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Corruption / rejection matrix for dtype and scale records
// ---------------------------------------------------------------------------

/// Mutate the `patch_w` INT8 storage of a fully quantized artifact and
/// re-encode (the checksum is legitimately re-stamped, the manifest
/// keeps the original records), returning the decode-side rejection.
fn scale_corruption(artifact: &VimArtifact, mutate: &dyn Fn(&mut QuantTensor)) -> ArtifactError {
    let mut bad = artifact.clone();
    match &mut bad.weights.patch_w {
        WeightMat::I8(qt) => mutate(qt),
        WeightMat::F32(_) => panic!("patch_w is quantized under the full plan"),
    }
    let bytes = ArtifactStore::encode(&bad).unwrap();
    ArtifactStore::decode(&bytes).unwrap_err()
}

#[test]
fn corrupt_dtype_and_scale_records_rejected_typed() {
    let (_, weights) = fully_quantized_micro_s(5);
    let artifact = VimArtifact::from_weights(weights, None, prov("matrix")).unwrap();
    let good = ArtifactStore::encode(&artifact).unwrap();
    assert!(ArtifactStore::decode(&good).is_ok(), "reference must decode");

    // An i8 dtype record on a precision-sensitive tensor is refused at
    // the manifest gate, before any blob bytes are interpreted.
    let mut hostile = artifact.manifest.clone();
    let idx = hostile
        .tensors
        .iter()
        .position(|t| !quantizable_tensor(&t.name))
        .expect("schema has sensitive tensors");
    hostile.tensors[idx].dtype = TensorDtype::I8;
    match hostile.forward_config() {
        Err(ArtifactError::DtypeForbidden { name }) => {
            assert_eq!(name, hostile.tensors[idx].name);
        }
        other => panic!("dtype denylist gate: {other:?}"),
    }

    // Manifest/weights dtype drift: the manifest claims f32 for a tensor
    // stored as i8 — the encoder's byte accounting refuses to write it.
    let mut drifted = artifact.clone();
    let qidx = drifted
        .manifest
        .tensors
        .iter()
        .position(|t| t.dtype == TensorDtype::I8)
        .expect("reference has i8 records");
    drifted.manifest.tensors[qidx].dtype = TensorDtype::F32;
    assert!(
        matches!(ArtifactStore::encode(&drifted), Err(ArtifactError::ConfigMismatch { .. })),
        "dtype drift gate"
    );

    // Scale records: non-positive and non-finite scales fail the decode
    // validity check; a drifted (but valid-looking) scale fails the
    // absmax integrity re-computation. Quadrupling the *largest* scale
    // provably moves the dequantized absmax: at percentile 1.0 every
    // nonzero column holds a +/-127 code, so absmax = 127 * max(scales).
    let e = scale_corruption(&artifact, &|qt| qt.scales[0] = -qt.scales[0]);
    assert!(matches!(e, ArtifactError::TensorCorrupt { .. }), "negative scale: {e}");
    let e = scale_corruption(&artifact, &|qt| qt.scales[0] = f32::NAN);
    assert!(matches!(e, ArtifactError::TensorCorrupt { .. }), "non-finite scale: {e}");
    let e = scale_corruption(&artifact, &|qt| {
        let j = (0..qt.scales.len()).max_by(|&a, &b| qt.scales[a].total_cmp(&qt.scales[b]));
        qt.scales[j.unwrap()] *= 4.0;
    });
    assert!(matches!(e, ArtifactError::TensorCorrupt { .. }), "drifted scale: {e}");

    // A v2 file whose header is patched down to v1 (checksum re-stamped)
    // is caught by the manifest/header version cross-check — dtype
    // records must never load under a version that predates them.
    let mut masquerade = good.clone();
    masquerade[8..12].copy_from_slice(&1u32.to_le_bytes());
    let n = masquerade.len();
    let c = fnv1a64(&masquerade[..n - 8]);
    masquerade[n - 8..].copy_from_slice(&c.to_le_bytes());
    match ArtifactStore::decode(&masquerade) {
        Err(ArtifactError::Manifest(detail)) => {
            assert!(detail.contains("header says 1"), "version cross-check detail: {detail}");
        }
        other => panic!("header/manifest version gate: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Precision search determinism
// ---------------------------------------------------------------------------

/// Same weights, images, and options -> identical plans (accepted sites
/// with their percentiles AND rejections), and every accepted site is an
/// eligible tensor. The search is the only heuristic stage of the
/// pipeline; everything downstream being bitwise makes its determinism
/// the whole reproducibility story.
#[test]
fn weight_precision_search_is_deterministic() {
    let cfg = ForwardConfig::micro_s();
    let weights = VimWeights::init(&cfg, 12);
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let imgs: Vec<Vec<f32>> = (0..3).map(|i| rand_image(70 + i, cfg.input_len())).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let opts = WeightQuantOpts { samples: refs.len(), ..WeightQuantOpts::default() };

    let p1 = weights.search_weight_quant(&tables, &scan, &refs, &opts).unwrap();
    let p2 = weights.search_weight_quant(&tables, &scan, &refs, &opts).unwrap();
    assert_eq!(p1, p2, "search must be run-to-run deterministic");

    let candidates = weights.weight_quant_candidates();
    assert_eq!(
        p1.sites.len() + p1.rejected.len(),
        candidates.len(),
        "every candidate is either accepted or rejected"
    );
    for (name, pct) in &p1.sites {
        assert!(quantizable_tensor(name), "accepted site {name} is not eligible");
        assert!(*pct > 0.0 && *pct <= 1.0, "site {name}: percentile {pct} out of range");
    }
    for (name, _) in &p1.rejected {
        assert!(candidates.contains(name), "rejected site {name} is not a candidate");
    }

    // Applying the plan is itself deterministic: two applications yield
    // byte-identical artifacts.
    let apply = || {
        let mut w = weights.clone();
        w.apply_weight_quant(&p1).unwrap();
        ArtifactStore::encode(&VimArtifact::from_weights(w, None, prov("det")).unwrap()).unwrap()
    };
    assert_eq!(apply(), apply(), "plan application must be byte-deterministic");
}
