//! Serving-invariance property (the serving-layer mirror of
//! `prop_chunked_scan_schedule_invariant`): inference routed through the
//! coordinator — ANY worker count, batch policy, queue depth and client
//! interleaving — must return per-request logits *bit-identical* to a
//! direct `NativeBackend` call on the same image.
//!
//! Since API v1 the `ServerHandle` used here is a shim over the
//! multi-model `Engine`, so these properties transitively pin the engine
//! hot path too (the multi-variant cases live in
//! `rust/tests/engine_props.rs`); `v0_shim_and_engine_agree_bitwise`
//! pins the shim itself against the typed surface.
//!
//! Hand-rolled harness (proptest is unavailable offline): `Pcg` provides
//! deterministic shrink-free random cases, 100+ per property.

use mamba_x::config::VimModel;
use mamba_x::coordinator::{BatchPolicy, EngineBuilder, InferenceRequest, Request, Server};
use mamba_x::runtime::{
    native::synthetic_image, InferenceBackend, ModelSpec, NativeBackend, Tensor,
};
use mamba_x::util::Pcg;
use mamba_x::vision::ForwardConfig;

/// Small-but-real model so 100+ serving cases stay fast in debug builds:
/// 2 bidirectional blocks, E=32, N=4, L=5 — every datapath stage of the
/// micro model, an order of magnitude fewer multiplies.
fn prop_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

#[test]
fn prop_serving_equals_direct_inference() {
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let mut rng = Pcg::new(0x5EED5);
    for case in 0..110u64 {
        let workers = rng.usize_in(1, 4);
        let max_batch = rng.usize_in(1, 8);
        let max_wait_us = rng.usize_in(0, 1500) as u64;
        let n_clients = rng.usize_in(1, 3);
        let per_client = rng.usize_in(1, 4);
        let weight_seed = 100 + (case % 7); // vary weights across cases too
        let image_seed = case;

        let server =
            Server::new(BatchPolicy { max_batch, max_wait_us }).queue_depth(64);
        let model_cfg = cfg.clone();
        let (handle, join) =
            server.spawn_pool(workers, move |_w| Ok(NativeBackend::new(&model_cfg, weight_seed)));

        let mut clients = Vec::new();
        for c in 0..n_clients {
            let h = handle.clone();
            let shape = cfg.input_shape();
            clients.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_client {
                    let id = (c * per_client + i) as u64;
                    let data = synthetic_image(image_seed, id, n_elems);
                    let req =
                        InferenceRequest { id, image: Tensor::new(shape.clone(), data).unwrap() };
                    let resp = h.infer(req).expect("queue depth 64 never rejects here");
                    got.push((resp.id, resp.logits));
                }
                got
            }));
        }
        let mut responses = Vec::new();
        for c in clients {
            responses.extend(c.join().unwrap());
        }
        drop(handle);
        let metrics = join.join().expect("pool joins cleanly");
        assert_eq!(responses.len(), n_clients * per_client, "case {case}");
        assert_eq!(metrics.count(), responses.len(), "case {case}");

        // Direct single-backend oracle: bit-identical logits per request.
        let mut direct = NativeBackend::new(&cfg, weight_seed);
        for (id, logits) in responses {
            let img = Tensor::new(cfg.input_shape(), synthetic_image(image_seed, id, n_elems))
                .unwrap();
            let want = direct.infer(&img).unwrap();
            assert_eq!(
                logits, want,
                "case {case} req {id}: served logits diverge \
                 (workers={workers} max_batch={max_batch} wait={max_wait_us})"
            );
        }
    }
}

#[test]
fn prop_response_ids_match_requests() {
    // Batching must never cross wires: response id == request id, and the
    // logits for distinct images differ (the backend is not constant).
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let server = Server::new(BatchPolicy { max_batch: 4, max_wait_us: 300 });
    let model_cfg = cfg.clone();
    let (handle, join) = server.spawn_pool(3, move |_w| Ok(NativeBackend::new(&model_cfg, 1)));
    let mut logits_seen = Vec::new();
    for id in 0..24u64 {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(9, id, n_elems)).unwrap();
        let resp = handle.infer(InferenceRequest { id, image: img }).unwrap();
        assert_eq!(resp.id, id);
        logits_seen.push(resp.logits);
    }
    drop(handle);
    join.join().unwrap();
    logits_seen.dedup();
    assert!(logits_seen.len() > 1, "distinct images must yield distinct logits");
}

/// The v0 shim and the typed v1 engine must serve bit-identical logits
/// for the same backend/seed — the migration is a pure surface change.
#[test]
fn v0_shim_and_engine_agree_bitwise() {
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let seed = 77u64;

    let server = Server::new(BatchPolicy { max_batch: 4, max_wait_us: 200 });
    let v0_cfg = cfg.clone();
    let (handle, v0_join) =
        server.spawn_pool(2, move |_w| Ok(NativeBackend::new(&v0_cfg, seed)));

    let v1_cfg = cfg.clone();
    let (engine, v1_join) = EngineBuilder::new()
        .workers(2)
        .policy(BatchPolicy { max_batch: 4, max_wait_us: 200 })
        .register(ModelSpec::new(
            "prop@dynamic",
            NativeBackend::factory(
                mamba_x::runtime::ModelSource::RandomInit { config: v1_cfg, seed },
                None,
                None,
            )
            .unwrap(),
        ))
        .unwrap()
        .build()
        .unwrap();

    for id in 0..12u64 {
        let data = synthetic_image(3, id, n_elems);
        let v0 = handle
            .infer(InferenceRequest {
                id,
                image: Tensor::new(cfg.input_shape(), data.clone()).unwrap(),
            })
            .unwrap();
        let v1 = engine
            .infer(Request::new("prop@dynamic", id, Tensor::new(cfg.input_shape(), data).unwrap()))
            .unwrap();
        assert_eq!(v0.logits, v1.logits, "request {id}: v0 and v1 diverge");
        assert_eq!(v1.model, "prop@dynamic");
    }
    drop(handle);
    drop(engine);
    assert_eq!(v0_join.join().unwrap().count(), 12);
    assert_eq!(v1_join.join().unwrap().completed(), 12);
}
