//! Properties of the `VimArtifact` model-artifact subsystem
//! (hand-rolled harness: proptest is unavailable offline; `Pcg` provides
//! deterministic shrink-free random cases).
//!
//! The contract under test:
//!
//! * save -> load -> forward is bitwise identical to the in-memory
//!   weights it was saved from, across random geometries (arch family x
//!   image size x channel count x class count), with and without an
//!   embedded calibration table;
//! * an artifact's embedded calibration is indistinguishable from the
//!   same table side-loaded via `--calib` (`with_calib`) — one file
//!   replaces the two-file flow bit-for-bit;
//! * corruption in any section — magic, version, lengths, manifest
//!   geometry/arch/shapes, tensor bytes, integrity records, embedded
//!   calibration — is rejected with the *typed* [`ArtifactError`]
//!   variant naming the failure, never a silent fallback;
//! * the committed golden fixture (`rust/tests/data/artifact_v1.bin`,
//!   written by `python/compile/make_artifact_golden.py`) decodes to the
//!   exact formula weights and calibration it encodes — pinning the v1
//!   byte layout across languages even as the encoder writes v2
//!   (quantized-artifact properties live in
//!   `rust/tests/quant_weight_props.rs`).

use std::path::PathBuf;
use std::sync::Arc;

use mamba_x::config::MambaXConfig;
use mamba_x::quant::CalibTable;
use mamba_x::runtime::{
    fnv1a64, ArtifactError, ArtifactStore, InferenceBackend, ModelSource, NativeBackend,
    Provenance, VimArtifact,
};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::Pcg;
use mamba_x::vision::{vim_tensor_schema, ForwardConfig, ScanExec, VimWeights};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/artifact_v1.bin")
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mamba_x_artifact_props_{}_{tag}", std::process::id()))
}

fn rand_image(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..len).map(|_| rng.f32_in(-1.0, 1.0)).collect()
}

fn prov(detail: &str) -> Provenance {
    Provenance { tool: "artifact_props".to_string(), detail: detail.to_string() }
}

/// PROPERTY: save -> load -> forward ≡ in-memory, over random geometries.
/// Half the cases embed a calibration table; for those the loaded backend
/// must also equal the in-memory weights with the same table side-loaded.
#[test]
fn prop_save_load_forward_bitwise_over_geometries() {
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let mut rng = Pcg::new(0xA27_1FAC);
    for case in 0..6u64 {
        let arch = ["micro_s", "micro", "micro_l"][rng.usize_in(0, 2)];
        let model = mamba_x::config::VimModel::by_name(arch).unwrap();
        let cfg = ForwardConfig {
            model,
            img: 4 * rng.usize_in(2, 3), // 8 or 12, multiple of patch 4
            in_ch: rng.usize_in(1, 2),
            n_classes: rng.usize_in(2, 8),
        };
        let seed = 1000 + case;
        let weights = VimWeights::init(&cfg, seed);
        let embed_calib = case % 2 == 0;
        let calib = if embed_calib {
            let imgs: Vec<Vec<f32>> = (0..rng.usize_in(1, 2))
                .map(|i| rand_image(case * 31 + i as u64, cfg.input_len()))
                .collect();
            let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
            Some(weights.calibrate(&tables, &scan, &refs, 1.0).unwrap())
        } else {
            None
        };
        let artifact =
            VimArtifact::from_weights(weights.clone(), calib.clone(), prov("prop")).unwrap();
        let path = temp_path(&format!("prop_{case}.mxa"));
        ArtifactStore::save(&path, &artifact).unwrap();

        let loaded = ArtifactStore::open(&path).unwrap();
        assert_eq!(loaded.manifest, artifact.manifest, "case {case} ({arch})");
        assert_eq!(loaded.calib, calib, "case {case}: calibration round-trip");
        for ((name, a), (_, b)) in
            weights.named_tensors().iter().zip(loaded.weights.named_tensors())
        {
            assert_eq!(*a, b, "case {case}: tensor {name} drifted");
        }

        // End to end through the backend surface: the artifact source
        // serves bitwise what the in-memory construction serves.
        let mut from_artifact =
            NativeBackend::from_source(&ModelSource::Artifact(path.clone())).unwrap();
        assert_eq!(from_artifact.calib().is_some(), embed_calib);
        let mut in_memory = {
            let b = NativeBackend::new(&cfg, seed);
            match &calib {
                Some(t) => b.with_calib(Arc::new(t.clone())).unwrap(),
                None => b,
            }
        };
        for img_seed in 0..3u64 {
            let img = mamba_x::runtime::Tensor::new(
                cfg.input_shape(),
                rand_image(9000 + case * 10 + img_seed, cfg.input_len()),
            )
            .unwrap();
            assert_eq!(
                from_artifact.infer(&img).unwrap(),
                in_memory.infer(&img).unwrap(),
                "case {case} ({arch}) image {img_seed}: artifact serving diverged"
            );
        }

        // inspect() sees the same manifest without decoding the blob.
        let summary = ArtifactStore::inspect(&path).unwrap();
        assert_eq!(summary.manifest, artifact.manifest);
        assert_eq!(summary.params * 4, summary.weight_bytes);
        assert_eq!(summary.calib.is_some(), embed_calib);
        std::fs::remove_file(&path).ok();
    }
}

/// Embedded calibration ≡ `--calib` side-load: one artifact file must be
/// bit-equivalent to the weights + separate table JSON it replaces, both
/// directly and through the factory override path.
#[test]
fn embedded_calib_equals_side_loaded_table() {
    let cfg = ForwardConfig::micro_s();
    let seed = 21u64;
    let weights = VimWeights::init(&cfg, seed);
    let imgs: Vec<Vec<f32>> = (0..4).map(|i| rand_image(40 + i, cfg.input_len())).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let table = weights
        .calibrate(&SfuTables::fitted(), &MambaXConfig::default(), &refs, 1.0)
        .unwrap();

    // One file: weights + embedded table.
    let embedded_path = temp_path("embedded.mxa");
    ArtifactStore::save(
        &embedded_path,
        &VimArtifact::from_weights(weights.clone(), Some(table.clone()), prov("embed")).unwrap(),
    )
    .unwrap();
    // Two files: calib-free artifact + side-channel table JSON.
    let bare_path = temp_path("bare.mxa");
    ArtifactStore::save(
        &bare_path,
        &VimArtifact::from_weights(weights.clone(), None, prov("bare")).unwrap(),
    )
    .unwrap();
    let table_path = temp_path("table.json");
    table.save(&table_path).unwrap();
    let side_loaded = Arc::new(CalibTable::load(&table_path).unwrap());

    let mut embedded =
        NativeBackend::from_source(&ModelSource::Artifact(embedded_path.clone())).unwrap();
    assert!(embedded.calib().is_some());
    let factory_override = NativeBackend::factory(
        ModelSource::Artifact(bare_path.clone()),
        Some(Arc::clone(&side_loaded)),
        None,
    )
    .unwrap();
    let mut overridden = factory_override(0).unwrap();
    let mut in_memory = NativeBackend::new(&cfg, seed).with_calib(side_loaded).unwrap();

    for (i, img) in imgs.iter().enumerate() {
        let t = mamba_x::runtime::Tensor::new(cfg.input_shape(), img.clone()).unwrap();
        let want = in_memory.infer(&t).unwrap();
        assert_eq!(embedded.infer(&t).unwrap(), want, "image {i}: embedded != side-load");
        assert_eq!(overridden.infer(&t).unwrap(), want, "image {i}: override != side-load");
    }
    for p in [&embedded_path, &bare_path, &table_path] {
        std::fs::remove_file(p).ok();
    }
}

// ---------------------------------------------------------------------------
// Corruption / rejection matrix
// ---------------------------------------------------------------------------

/// Replace one occurrence of `find` with the same-length `replace`
/// (first or last match) and re-stamp the trailing checksum, so the
/// targeted gate — not the checksum — is what rejects.
fn patched(bytes: &[u8], find: &[u8], replace: &[u8], last: bool) -> Vec<u8> {
    assert_eq!(find.len(), replace.len(), "surgery must preserve lengths");
    let positions: Vec<usize> =
        (0..=bytes.len() - find.len()).filter(|&i| &bytes[i..i + find.len()] == find).collect();
    assert!(!positions.is_empty(), "pattern not found: {:?}", String::from_utf8_lossy(find));
    let pos = if last { *positions.last().unwrap() } else { positions[0] };
    let mut out = bytes.to_vec();
    out[pos..pos + find.len()].copy_from_slice(replace);
    let n = out.len();
    let c = fnv1a64(&out[..n - 8]);
    out[n - 8..].copy_from_slice(&c.to_le_bytes());
    out
}

fn reference_bytes(with_calib: bool) -> Vec<u8> {
    let cfg = ForwardConfig::micro_s();
    let weights = VimWeights::init(&cfg, 5);
    let calib = with_calib.then(|| {
        let img = rand_image(1, cfg.input_len());
        weights
            .calibrate(&SfuTables::fitted(), &MambaXConfig::default(), &[img.as_slice()], 1.0)
            .unwrap()
    });
    ArtifactStore::encode(&VimArtifact::from_weights(weights, calib, prov("matrix")).unwrap())
        .unwrap()
}

#[test]
fn corrupt_artifacts_rejected_typed() {
    let good = reference_bytes(true);
    assert!(ArtifactStore::decode(&good).is_ok(), "reference must decode");

    // Manifest geometry drifting from its arch: micro_s has d_model 48.
    let wrong_geom = patched(&good, b"\"d_model\":48", b"\"d_model\":49", false);
    assert!(
        matches!(ArtifactStore::decode(&wrong_geom), Err(ArtifactError::ConfigMismatch { .. })),
        "geometry gate"
    );

    // Unknown arch (same length, different name).
    let unknown_arch = patched(&good, b"\"arch\":\"micro_s\"", b"\"arch\":\"nicro_s\"", false);
    match ArtifactStore::decode(&unknown_arch) {
        Err(ArtifactError::ArchUnknown { arch }) => assert_eq!(arch, "nicro_s"),
        other => panic!("arch gate: {other:?}"),
    }

    // Tensor shape drift: patch_w is (patch_dim=16, d=48) for micro_s.
    let wrong_shape = patched(&good, b"\"shape\":[16,48]", b"\"shape\":[48,16]", false);
    assert!(
        matches!(ArtifactStore::decode(&wrong_shape), Err(ArtifactError::ShapeMismatch { .. })),
        "shape gate"
    );

    // Embedded calibration for a different model (the calib JSON is the
    // only section containing a "model" key).
    let wrong_calib = patched(&good, b"\"model\":\"micro_s\"", b"\"model\":\"micro_x\"", true);
    assert!(
        matches!(ArtifactStore::decode(&wrong_calib), Err(ArtifactError::Calib(_))),
        "calibration gate"
    );

    // A lying per-tensor integrity record survives the checksum (it is
    // re-stamped) but not the absmax re-computation.
    let cfg = ForwardConfig::micro_s();
    let weights = VimWeights::init(&cfg, 5);
    let mut lying = VimArtifact::from_weights(weights, None, prov("lying")).unwrap();
    lying.manifest.tensors[0].absmax += 1.0;
    let lying_bytes = ArtifactStore::encode(&lying).unwrap();
    assert!(
        matches!(ArtifactStore::decode(&lying_bytes), Err(ArtifactError::TensorCorrupt { .. })),
        "integrity gate"
    );

    // Random single-bit flips anywhere must be rejected (checksum or a
    // structural gate — typed either way, never a silent load).
    let mut rng = Pcg::new(0xB17F11);
    for _ in 0..16 {
        let mut flipped = good.clone();
        let pos = rng.usize_in(0, flipped.len() - 1);
        flipped[pos] ^= 1 << rng.usize_in(0, 7);
        if flipped == good {
            continue;
        }
        assert!(ArtifactStore::decode(&flipped).is_err(), "bit flip at {pos} accepted");
    }

    // Truncation at every section boundary and a few interior points.
    for cut in [0usize, 4, 8, 15, 16, 40, good.len() / 2, good.len() - 9, good.len() - 1] {
        let err = ArtifactStore::decode(&good[..cut]).unwrap_err();
        assert!(
            matches!(err, ArtifactError::Truncated { .. } | ArtifactError::Checksum { .. }),
            "cut at {cut}: {err}"
        );
    }
}

/// The same gates fire through the file-based path (`open` / `inspect`),
/// and `inspect` structurally validates without reading the blob.
#[test]
fn file_level_rejections_are_typed() {
    let good = reference_bytes(false);
    let write = |tag: &str, bytes: &[u8]| -> PathBuf {
        let p = temp_path(tag);
        std::fs::write(&p, bytes).unwrap();
        p
    };

    let missing = temp_path("missing.mxa");
    assert!(matches!(ArtifactStore::open(&missing), Err(ArtifactError::Io { .. })));
    assert!(matches!(ArtifactStore::inspect(&missing), Err(ArtifactError::Io { .. })));

    let mut future = good.clone();
    future[8..12].copy_from_slice(&7u32.to_le_bytes());
    let n = future.len();
    let c = fnv1a64(&future[..n - 8]);
    future[n - 8..].copy_from_slice(&c.to_le_bytes());
    let p = write("future.mxa", &future);
    assert!(matches!(
        ArtifactStore::open(&p),
        Err(ArtifactError::FutureVersion { found: 7 })
    ));
    assert!(matches!(
        ArtifactStore::inspect(&p),
        Err(ArtifactError::FutureVersion { found: 7 })
    ));
    std::fs::remove_file(&p).ok();

    let mut foreign = good.clone();
    foreign[..8].copy_from_slice(b"NOTMAMBA");
    let p = write("foreign.mxa", &foreign);
    assert!(matches!(ArtifactStore::open(&p), Err(ArtifactError::ForeignMagic { .. })));
    assert!(matches!(ArtifactStore::inspect(&p), Err(ArtifactError::ForeignMagic { .. })));
    std::fs::remove_file(&p).ok();

    // Truncated mid-blob: inspect's section accounting catches it even
    // though it never reads the tensor bytes.
    let p = write("truncated.mxa", &good[..good.len() - 20]);
    assert!(matches!(ArtifactStore::open(&p), Err(ArtifactError::Truncated { .. })));
    assert!(matches!(ArtifactStore::inspect(&p), Err(ArtifactError::Truncated { .. })));
    std::fs::remove_file(&p).ok();

    // Trailing bytes after the checksum.
    let mut trailing = good.clone();
    trailing.extend_from_slice(b"junk");
    let p = write("trailing.mxa", &trailing);
    assert!(matches!(ArtifactStore::open(&p), Err(ArtifactError::TrailingBytes { extra: 4 })));
    assert!(matches!(
        ArtifactStore::inspect(&p),
        Err(ArtifactError::TrailingBytes { extra: 4 })
    ));
    std::fs::remove_file(&p).ok();
}

// ---------------------------------------------------------------------------
// Golden fixture: the byte layout, pinned across languages
// ---------------------------------------------------------------------------

/// The committed fixture's weight formula (mirrored from
/// `make_artifact_golden.py`): tensor `t`, element `k` ->
/// `((t*1009 + k*31) % 2001 - 1000) / 8192`, exact in f32.
fn golden_value(t: usize, k: usize) -> f32 {
    (((t * 1009 + k * 31) % 2001) as f32 - 1000.0) / 8192.0
}

#[test]
fn golden_artifact_v1_decodes_bitwise() {
    let artifact = ArtifactStore::open(golden_path()).unwrap();
    let m = &artifact.manifest;
    // The fixture pins the v1 layout: it must keep decoding as v1 (not
    // be silently rewritten) even though the encoder now writes v2.
    assert_eq!(m.version, 1);
    assert_eq!(m.arch, "micro_s");
    assert_eq!((m.img, m.in_ch, m.n_classes), (8, 1, 3));
    assert_eq!(m.provenance.tool, "make_artifact_golden.py");

    let cfg = m.forward_config().unwrap();
    assert_eq!(cfg.model.d_model, 48);
    assert_eq!(vim_tensor_schema(&cfg).len(), m.tensors.len());

    // Every tensor matches the generation formula bit-for-bit.
    for (t, (name, view)) in artifact.weights.named_tensors().iter().enumerate() {
        let data = view.as_f32().expect("v1 artifacts decode to dense f32 tensors");
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(
                v.to_bits(),
                golden_value(t, k).to_bits(),
                "tensor {t} ({name}) element {k}"
            );
        }
    }

    // The embedded calibration follows its range formulas; the loader
    // already re-derived and cross-checked the stored shifts.
    let table = artifact.calib.as_ref().expect("golden embeds a calibration table");
    assert_eq!(table.model, "micro_s");
    assert_eq!(table.sites.len(), 2 * cfg.model.n_blocks);
    assert_eq!((table.samples, table.percentile), (4, 1.0));
    for (s, site) in table.sites.iter().enumerate() {
        assert_eq!((site.block, site.dir), (s / 2, s % 2));
        assert_eq!(site.sq.len(), cfg.model.d_inner());
        for c in 0..site.sq.len() {
            let j = (s + c) % 4;
            assert_eq!(
                site.da_max[c].to_bits(),
                (0.8f32 * (2f32).powi(-(j as i32))).to_bits(),
                "site {s} channel {c} da_max"
            );
            assert_eq!(site.shift[c], 7 + j as i32, "site {s} channel {c} shift");
            assert_eq!(
                site.dbu_max[c].to_bits(),
                (((s * 5 + c) % 7 + 1) as f32 * 0.25).to_bits(),
                "site {s} channel {c} dbu_max"
            );
        }
    }

    // The fixture serves: finite logits, identical through the backend
    // and the raw weights (static scan via the embedded table).
    let img = rand_image(77, cfg.input_len());
    let mut backend = NativeBackend::from_source(&ModelSource::Artifact(golden_path())).unwrap();
    let served = backend
        .infer(&mamba_x::runtime::Tensor::new(cfg.input_shape(), img.clone()).unwrap())
        .unwrap();
    assert_eq!(served.len(), 3);
    assert!(served.iter().all(|v| v.is_finite()));
    let mut exec = ScanExec::Static(table);
    let direct = artifact.weights.forward_batch_ex(
        &SfuTables::fitted(),
        &MambaXConfig::default(),
        &[img.as_slice()],
        &mut exec,
    );
    assert_eq!(served, direct[0], "backend and raw-weights forward diverge");
}
