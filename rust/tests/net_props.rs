//! Network-layer properties (the acceptance gate for the HTTP serving
//! front-end):
//!
//! * the HTTP/1.1 framing layer never panics: arbitrarily fragmented,
//!   truncated, or garbage input maps to a typed [`FrameError`] (or a
//!   valid message), and pipelined messages parse identically however
//!   the bytes are chunked;
//! * over a real socket, the status mapping is one-to-one with the
//!   typed engine surface: 200 bitwise-correct logits, 404 unknown
//!   model, 400 malformed bodies, 429 Full / ClientQuota with a
//!   `retry-after`, 503 + answered in-flight requests on graceful
//!   drain;
//! * front-end counters reconcile exactly with the engine's own report
//!   (one accounting point per refusal class);
//! * the seeded closed-loop loadgen completes every request against a
//!   live server and its artifact reconciles with both reports.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use mamba_x::coordinator::{BatchPolicy, Engine, EngineBuilder, EngineJoin, EngineReport};
use mamba_x::net::http::{write_request, write_response};
use mamba_x::net::{
    loadgen, ArrivalMode, BoundServer, FrameError, HttpConn, HttpLimits, LoadgenConfig,
    ModelMeta, NetConfig, NetReport,
};
use mamba_x::runtime::{native::synthetic_image, InferenceBackend, ModelSpec, Tensor};
use mamba_x::util::{Json, Pcg};

// ---------------------------------------------------------------------------
// Framing properties (in-memory, seeded fragmentation)
// ---------------------------------------------------------------------------

/// Reader that hands out the wire bytes in random 1..=7 byte fragments,
/// so every parser code path that resumes across `read` boundaries is
/// exercised.
struct FragmentReader {
    data: Vec<u8>,
    pos: usize,
    rng: Pcg,
}

impl FragmentReader {
    fn new(data: Vec<u8>, seed: u64) -> Self {
        FragmentReader { data, pos: 0, rng: Pcg::new(seed) }
    }
}

impl Read for FragmentReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let n = self.rng.usize_in(1, 7).min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Build a random-but-valid request wire image from the seeded stream.
fn random_request_wire(rng: &mut Pcg) -> (Vec<u8>, usize) {
    let n = rng.usize_in(1, 4);
    let mut wire = Vec::new();
    for k in 0..n {
        let body: Vec<u8> =
            (0..rng.usize_in(0, 50)).map(|_| rng.below(256) as u8).collect();
        let method = ["GET", "POST", "PUT"][rng.usize_in(0, 2)];
        let target = format!("/path/{k}");
        let extra = format!("v{}", rng.below(1000));
        write_request(&mut wire, method, &target, &[("x-extra", extra.as_str())], &body)
            .unwrap();
    }
    (wire, n)
}

#[test]
fn prop_fragmentation_is_invisible_to_the_parser() {
    let mut rng = Pcg::new(0xF00D);
    for case in 0..50u64 {
        let (wire, n) = random_request_wire(&mut rng);
        // Parse once over whole-buffer reads, once over fragments.
        let mut whole = HttpConn::new(std::io::Cursor::new(wire.clone()), HttpLimits::default());
        let mut frag =
            HttpConn::new(FragmentReader::new(wire, 1000 + case), HttpLimits::default());
        for i in 0..n {
            let a = whole.read_request().unwrap();
            let b = frag.read_request().unwrap();
            assert_eq!(a, b, "case {case} message {i}");
        }
        assert_eq!(whole.read_request().unwrap_err(), FrameError::Eof);
        assert_eq!(frag.read_request().unwrap_err(), FrameError::Eof);
    }
}

#[test]
fn prop_truncation_anywhere_is_typed_never_a_panic() {
    let mut rng = Pcg::new(0xBEEF);
    for case in 0..30u64 {
        let (wire, _) = random_request_wire(&mut rng);
        for _ in 0..20 {
            let cut = rng.usize_in(0, wire.len() - 1);
            let mut conn = HttpConn::new(
                FragmentReader::new(wire[..cut].to_vec(), 7 + case),
                HttpLimits::default(),
            );
            // Complete prefixes parse; the first incomplete message is a
            // clean Eof (between messages) or Truncated (mid-message).
            loop {
                match conn.read_request() {
                    Ok(_) => continue,
                    Err(FrameError::Eof) | Err(FrameError::Truncated) => break,
                    Err(other) => panic!("case {case} cut {cut}: unexpected {other:?}"),
                }
            }
        }
    }
}

#[test]
fn prop_garbage_bytes_never_panic() {
    let mut rng = Pcg::new(0xDEAD);
    for _ in 0..200 {
        let junk: Vec<u8> = (0..rng.usize_in(0, 300)).map(|_| rng.below(256) as u8).collect();
        let mut conn = HttpConn::new(std::io::Cursor::new(junk), HttpLimits::default());
        // Any outcome is fine as long as it is a value, not a panic.
        let _ = conn.read_request();
    }
}

#[test]
fn prop_content_length_abuse_is_refused_before_reading_bodies() {
    let mut rng = Pcg::new(0x5EED);
    let limits = HttpLimits { max_head_bytes: 4096, max_body_bytes: 1 << 20 };
    for _ in 0..50 {
        // Oversize lengths are refused from the head alone — no body
        // bytes follow and none are needed.
        let over = (1u64 << 20) + 1 + rng.below(1 << 40);
        let wire = format!("POST /v1/infer HTTP/1.1\r\ncontent-length: {over}\r\n\r\n");
        let err = HttpConn::new(std::io::Cursor::new(wire.into_bytes()), limits)
            .read_request()
            .unwrap_err();
        assert!(
            matches!(err, FrameError::BodyTooLarge { .. }),
            "content-length {over}: {err:?}"
        );
        assert_eq!(err.status().unwrap().0, 413);
        // Non-numeric lengths are typed 400s.
        let bad = format!("{}x{}", rng.below(100), rng.below(100));
        let wire = format!("POST / HTTP/1.1\r\ncontent-length: {bad}\r\n\r\n");
        let err = HttpConn::new(std::io::Cursor::new(wire.into_bytes()), limits)
            .read_request()
            .unwrap_err();
        assert!(matches!(err, FrameError::BadContentLength(_)), "{bad}: {err:?}");
        assert_eq!(err.status().unwrap().0, 400);
    }
}

#[test]
fn prop_response_writer_round_trips_through_fragmentation() {
    let mut rng = Pcg::new(0xCAFE);
    for case in 0..30u64 {
        let mut wire = Vec::new();
        let mut sent = Vec::new();
        for _ in 0..rng.usize_in(1, 3) {
            let status = [200u16, 400, 404, 429, 503][rng.usize_in(0, 4)];
            let body: Vec<u8> =
                (0..rng.usize_in(0, 40)).map(|_| rng.below(256) as u8).collect();
            write_response(&mut wire, status, "Reason", &[("x-t", "1")], &body, false).unwrap();
            sent.push((status, body));
        }
        let mut conn =
            HttpConn::new(FragmentReader::new(wire, 40 + case), HttpLimits::default());
        for (status, body) in &sent {
            let resp = conn.read_response().unwrap();
            assert_eq!(resp.status, *status);
            assert_eq!(&resp.body, body);
            assert_eq!(resp.header("x-t"), Some("1"));
        }
    }
}

// ---------------------------------------------------------------------------
// Socket end-to-end: engine semantics over the wire
// ---------------------------------------------------------------------------

/// Deterministic test backend: logits = [sum, count] of the image, with
/// an optional per-inference service delay to hold requests in flight.
struct Summing {
    delay: Duration,
}

impl InferenceBackend for Summing {
    fn name(&self) -> &'static str {
        "summing"
    }

    fn infer(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(vec![image.data.iter().sum::<f32>(), image.data.len() as f32])
    }
}

/// Engine hosting one 2-element "sum" model with the given pool shape.
fn sum_engine(
    workers: usize,
    max_batch: usize,
    max_wait_us: u64,
    queue_depth: usize,
    client_quota: usize,
    delay_ms: u64,
) -> (Engine, EngineJoin, Vec<ModelMeta>) {
    let spec = ModelSpec::new(
        "sum",
        Arc::new(move |_w| {
            Ok(Box::new(Summing { delay: Duration::from_millis(delay_ms) })
                as Box<dyn InferenceBackend>)
        }),
    );
    let (engine, join) = EngineBuilder::new()
        .workers(workers)
        .policy(BatchPolicy { max_batch, max_wait_us })
        .queue_depth(queue_depth)
        .client_quota(client_quota)
        .register(spec)
        .unwrap()
        .build()
        .unwrap();
    let metas = vec![ModelMeta { name: "sum".to_string(), input_shape: vec![2] }];
    (engine, join, metas)
}

/// Bind on an ephemeral port and serve on a background thread.
fn spawn_http(
    engine: Engine,
    metas: Vec<ModelMeta>,
) -> (SocketAddr, std::thread::JoinHandle<Result<NetReport>>) {
    let bound = BoundServer::bind(NetConfig::new("127.0.0.1:0")).unwrap();
    let addr = bound.local_addr().unwrap();
    let handle = std::thread::spawn(move || bound.serve(engine, metas));
    (addr, handle)
}

fn connect(addr: SocketAddr) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream, HttpLimits::default())
}

/// One-shot POST on a fresh connection.
fn post(addr: SocketAddr, target: &str, body: &[u8]) -> mamba_x::net::RawResponse {
    let mut conn = connect(addr);
    write_request(conn.stream_mut(), "POST", target, &[], body).unwrap();
    conn.read_response().unwrap()
}

fn shutdown(addr: SocketAddr) {
    let resp = post(addr, "/admin/shutdown", b"");
    assert_eq!(resp.status, 200);
}

fn body_json(resp: &mamba_x::net::RawResponse) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

fn error_code(resp: &mamba_x::net::RawResponse) -> String {
    body_json(resp).get("error").unwrap().str().unwrap().to_string()
}

/// ACCEPTANCE: inference over HTTP is bitwise identical to the backend,
/// for both inline payloads and server-side seeded images; unknown
/// models and malformed bodies get typed statuses; and every counter
/// reconciles between the front-end report and the engine report.
#[test]
fn http_round_trip_is_bitwise_and_reports_reconcile() {
    let (engine, join, metas) = sum_engine(2, 4, 1000, 64, 0, 0);
    let (addr, server) = spawn_http(engine, metas);

    // healthz advertises the hosted model and its payload contract.
    let mut conn = connect(addr);
    write_request(conn.stream_mut(), "GET", "/healthz", &[], b"").unwrap();
    let health = conn.read_response().unwrap();
    assert_eq!(health.status, 200);
    let hj = body_json(&health);
    assert_eq!(hj.get("status").unwrap().str().unwrap(), "ok");
    // Degradation surface: full worker pool, no respawns, breaker closed.
    assert_eq!(hj.get("workers_alive").unwrap().usize().unwrap(), 2);
    assert_eq!(hj.get("workers_total").unwrap().usize().unwrap(), 2);
    assert_eq!(hj.get("restarts").unwrap().usize().unwrap(), 0);
    assert_eq!(hj.get("models").unwrap().arr().unwrap().len(), 1);
    let m0 = &hj.get("models").unwrap().arr().unwrap()[0];
    assert_eq!(m0.get("name").unwrap().str().unwrap(), "sum");
    assert_eq!(m0.get("input_len").unwrap().usize().unwrap(), 2);
    assert_eq!(m0.get("breaker").unwrap().str().unwrap(), "closed");

    // Inline payload: logits bitwise = [1+2, 2].
    let ok = post(addr, "/v1/infer", br#"{"model":"sum","id":9,"image":[1.0,2.0]}"#);
    assert_eq!(ok.status, 200, "{:?}", String::from_utf8_lossy(&ok.body));
    let oj = body_json(&ok);
    assert_eq!(oj.get("id").unwrap().usize().unwrap(), 9);
    assert_eq!(oj.get("model").unwrap().str().unwrap(), "sum");
    let logits: Vec<f64> =
        oj.get("logits").unwrap().arr().unwrap().iter().map(|v| v.num().unwrap()).collect();
    assert_eq!(logits, [3.0, 2.0]);

    // Seeded payload: the server expands synthetic_image(seed, id, 2)
    // itself; expected sum computed from the same deterministic stream.
    let seeded = post(addr, "/v1/infer", br#"{"model":"sum","id":4,"image_seed":11}"#);
    assert_eq!(seeded.status, 200);
    let want: f32 = synthetic_image(11, 4, 2).iter().sum();
    let got = body_json(&seeded).get("logits").unwrap().arr().unwrap()[0].num().unwrap();
    assert_eq!(got as f32, want, "seeded inference must be bitwise reproducible");

    // Unknown model: 404, counted by the ENGINE (single accounting
    // point), with the hosted list in the detail.
    let nf = post(addr, "/v1/infer", br#"{"model":"nope","image":[1.0]}"#);
    assert_eq!(nf.status, 404);
    assert_eq!(error_code(&nf), "unknown_model");

    // Malformed bodies: typed 400s, never accepted, never a panic.
    for bad in [
        &b"not json at all"[..],
        br#"{"model":"sum"}"#,
        br#"{"model":"sum","image":[1.0,2.0],"image_seed":3}"#,
        br#"{"model":"sum","image":[1.0,2.0,3.0]}"#,
        br#"{"model":"sum","image_seed":1,"typo":true}"#,
        br#"{"model":"sum","image_seed":1,"priority":"urgent"}"#,
    ] {
        let resp = post(addr, "/v1/infer", bad);
        assert_eq!(resp.status, 400, "{:?}", String::from_utf8_lossy(bad));
        assert_eq!(error_code(&resp), "bad_request");
    }

    // Unknown route: 404 with a distinct code (not engine-accounted).
    let nr = post(addr, "/v1/nope", b"{}");
    assert_eq!(nr.status, 404);
    assert_eq!(error_code(&nr), "not_found");

    // Malformed request line over the raw socket: typed 400, then close.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    raw.write_all(b"BOGUS\r\n\r\n").unwrap();
    let mut rconn = HttpConn::new(raw, HttpLimits::default());
    let resp = rconn.read_response().unwrap();
    assert_eq!(resp.status, 400);
    assert!(resp.close);

    shutdown(addr);
    let net = server.join().unwrap().unwrap();
    let report: EngineReport = join.join().unwrap();

    // Reconciliation: the two OK inferences are the engine's only
    // completions; the unknown-model 404 is the engine's count; the
    // front-end 400s never reached the engine.
    assert_eq!(net.ok, 2);
    assert_eq!(report.merged().count(), 2);
    assert_eq!(net.unknown_model, 1);
    assert_eq!(report.rejected_unknown_model, 1);
    assert_eq!(net.bad_request, 7, "6 bad bodies + 1 bad request line");
    assert_eq!(net.not_found, 1);
    assert_eq!(report.merged().rejected(), 0, "no admission rejections in this test");
}

/// Pipelined requests on one connection are answered in order.
#[test]
fn http_pipelining_answers_in_order() {
    let (engine, join, metas) = sum_engine(1, 4, 500, 64, 0, 0);
    let (addr, server) = spawn_http(engine, metas);

    let mut wire = Vec::new();
    let one = br#"{"model":"sum","id":1,"image":[1.0,1.0]}"#;
    let two = br#"{"model":"sum","id":2,"image":[2.0,2.0]}"#;
    write_request(&mut wire, "POST", "/v1/infer", &[], one).unwrap();
    write_request(&mut wire, "POST", "/v1/infer", &[], two).unwrap();
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut conn = HttpConn::new(stream, HttpLimits::default());
    conn.stream_mut().write_all(&wire).unwrap();
    for (want_id, want_sum) in [(1u64, 2.0), (2, 4.0)] {
        let resp = conn.read_response().unwrap();
        assert_eq!(resp.status, 200);
        let j = body_json(&resp);
        assert_eq!(j.get("id").unwrap().usize().unwrap() as u64, want_id);
        assert_eq!(j.get("logits").unwrap().arr().unwrap()[0].num().unwrap(), want_sum);
    }

    shutdown(addr);
    server.join().unwrap().unwrap();
    assert_eq!(join.join().unwrap().merged().count(), 2);
}

/// A full queue surfaces as 429 + retry-after with the engine's "full"
/// reason on the wire.
#[test]
fn http_backpressure_maps_to_429_full() {
    // depth 1, slow batch formation (300ms max_wait, max_batch 2): the
    // first request stays pending long enough for the second to hit a
    // full queue deterministically.
    let (engine, join, metas) = sum_engine(1, 2, 300_000, 1, 0, 0);
    let (addr, server) = spawn_http(engine, metas);

    let first = std::thread::spawn(move || {
        post(addr, "/v1/infer", br#"{"model":"sum","id":1,"priority":"high","image":[1.0,2.0]}"#)
    });
    std::thread::sleep(Duration::from_millis(80));
    let refused =
        post(addr, "/v1/infer", br#"{"model":"sum","id":2,"priority":"high","image":[3.0,4.0]}"#);
    assert_eq!(refused.status, 429);
    assert_eq!(error_code(&refused), "full");
    assert_eq!(refused.header("retry-after"), Some("1"));

    let ok = first.join().unwrap();
    assert_eq!(ok.status, 200, "the accepted request completes (accepted-never-shed)");

    shutdown(addr);
    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.ok, 1);
    assert_eq!(net.rejected_full, 1);
    assert_eq!(report.merged().rejected_full, 1, "front-end and engine agree");
}

/// Per-client quotas refuse the over-quota client specifically while
/// other clients proceed; counters reconcile end to end.
#[test]
fn http_client_quota_is_per_client_and_reconciles() {
    let (engine, join, metas) = sum_engine(1, 1, 0, 16, 1, 150);
    let (addr, server) = spawn_http(engine, metas);

    // Client "x" holds its one slot for ~150ms.
    let slow = std::thread::spawn(move || {
        post(addr, "/v1/infer", br#"{"model":"sum","id":1,"client":"x","image":[1.0,2.0]}"#)
    });
    std::thread::sleep(Duration::from_millis(40));
    // Same client, second in-flight request: refused as quota, not full.
    let refused =
        post(addr, "/v1/infer", br#"{"model":"sum","id":2,"client":"x","image":[1.0,2.0]}"#);
    assert_eq!(refused.status, 429);
    assert_eq!(error_code(&refused), "client_quota");
    // A different client is admitted (the queue has room).
    let other =
        post(addr, "/v1/infer", br#"{"model":"sum","id":3,"client":"y","image":[5.0,6.0]}"#);
    assert_eq!(other.status, 200);
    assert_eq!(slow.join().unwrap().status, 200);

    shutdown(addr);
    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.ok, 2);
    assert_eq!(net.rejected_quota, 1);
    assert_eq!(report.merged().rejected_quota, 1);
    assert_eq!(report.merged().count(), 2);
}

/// ACCEPTANCE: graceful drain — after /admin/shutdown the in-flight
/// request is answered, new connections get 503, and `serve` returns.
#[test]
fn http_graceful_drain_answers_in_flight_and_refuses_new() {
    let (engine, join, metas) = sum_engine(1, 1, 0, 16, 0, 200);
    let (addr, server) = spawn_http(engine, metas);

    // In-flight request held ~200ms by the backend.
    let inflight = std::thread::spawn(move || {
        post(addr, "/v1/infer", br#"{"model":"sum","id":1,"image":[1.0,2.0]}"#)
    });
    std::thread::sleep(Duration::from_millis(50));
    shutdown(addr);

    // A connection arriving after the drain began is refused with 503.
    let late = post(addr, "/v1/infer", br#"{"model":"sum","id":2,"image":[1.0,2.0]}"#);
    assert_eq!(late.status, 503);
    assert_eq!(error_code(&late), "shutting_down");

    // The in-flight request still completes with real results.
    let resp = inflight.join().unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(body_json(&resp).get("logits").unwrap().arr().unwrap()[0].num().unwrap(), 3.0);

    // serve() returns on its own — the drain finished.
    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.ok, 1);
    assert!(net.shutting_down >= 1);
    assert_eq!(report.merged().count(), 1);
}

/// ACCEPTANCE: the seeded closed-loop loadgen completes every request
/// against a live server and all three reports (loadgen artifact,
/// front-end counters, engine report) agree exactly.
#[test]
fn loadgen_closed_loop_reconciles_exactly() {
    let (engine, join, metas) = sum_engine(2, 4, 1000, 64, 0, 0);
    let (addr, server) = spawn_http(engine, metas);

    let mut cfg = LoadgenConfig::new(addr.to_string());
    cfg.requests = 24;
    cfg.clients = 3;
    cfg.mode = ArrivalMode::Closed;
    cfg.seed = 5;
    cfg.priorities = loadgen::parse_priority_mix("high=1,normal=1").unwrap();
    cfg.shutdown = true; // drain the server when done
    let artifact = loadgen::run(&cfg).unwrap();

    let n = |key: &str| artifact.get(key).unwrap().usize().unwrap();
    assert_eq!(artifact.get("format").unwrap().str().unwrap(), "mamba-x-serving-bench");
    assert_eq!(n("sent"), 24);
    assert_eq!(n("completed"), 24, "closed-loop against an idle server loses nothing");
    assert_eq!(n("transport_errors"), 0);
    let sp = artifact.get("speedups").unwrap().arr().unwrap();
    assert_eq!(sp[0].get("name").unwrap().str().unwrap(), "serving_goodput_ratio");
    assert_eq!(sp[0].get("speedup").unwrap().num().unwrap(), 1.0);
    assert!(artifact.get("goodput_rps").unwrap().num().unwrap() > 0.0);
    // Per-priority splits sum to the whole.
    let pp = artifact.get("per_priority").unwrap();
    let sent_by_tier: usize = ["low", "normal", "high"]
        .iter()
        .map(|t| pp.get(t).unwrap().get("sent").unwrap().usize().unwrap())
        .sum();
    assert_eq!(sent_by_tier, 24);
    assert_eq!(pp.get("low").unwrap().get("sent").unwrap().usize().unwrap(), 0);

    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.ok, 24, "front-end agrees with the loadgen");
    assert_eq!(report.merged().count(), 24, "engine agrees with the loadgen");
    assert_eq!(report.merged().rejected(), 0);
    assert_eq!(report.rejected_unknown_model, 0);
}

/// Open-loop mode drives the same reconciliation: every request is
/// accounted for in exactly one outcome class (none lost, none double-
/// counted), even when admission control sheds some of the burst.
#[test]
fn loadgen_open_loop_accounts_for_every_request() {
    // Small queue + priority mix so bursty arrivals can actually shed.
    let (engine, join, metas) = sum_engine(1, 2, 500, 4, 0, 2);
    let (addr, server) = spawn_http(engine, metas);

    let mut cfg = LoadgenConfig::new(addr.to_string());
    cfg.requests = 40;
    cfg.clients = 4;
    cfg.mode = ArrivalMode::Open { rate_rps: 2000.0, dist: loadgen::Dist::Bursty };
    cfg.seed = 9;
    cfg.priorities = loadgen::parse_priority_mix("high=1,normal=1,low=1").unwrap();
    cfg.shutdown = true;
    let artifact = loadgen::run(&cfg).unwrap();

    let n = |key: &str| artifact.get(key).unwrap().usize().unwrap() as u64;
    assert_eq!(n("sent"), 40);
    // The full ledger identity: every attempt (original or retry) lands
    // in exactly one outcome class. Retries are 0 here (default policy),
    // so attempts == sent.
    let accounted = n("completed")
        + n("rejected_full")
        + n("rejected_shed")
        + n("rejected_quota")
        + n("unknown_model")
        + n("bad_request")
        + n("shutting_down")
        + n("backend_error")
        + n("deadline_exceeded")
        + n("breaker_open")
        + n("timeouts")
        + n("transport_errors");
    assert_eq!(accounted, 40 + n("retries"), "every attempt lands in exactly one class");
    assert_eq!(n("retries"), 0, "retry policy is off by default");

    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.ok, n("completed"));
    assert_eq!(report.merged().count(), n("completed") as usize);
    assert_eq!(net.rejected_full + net.rejected_shed, n("rejected_full") + n("rejected_shed"));
    assert_eq!(
        report.merged().rejected_full + report.merged().rejected_shed,
        n("rejected_full") + n("rejected_shed"),
        "engine-side refusal accounting matches the wire"
    );
}

/// Priority is not dead config: under the same overloaded shape, low
/// tiers shed strictly before high (uses the fixed strict tiering).
#[test]
fn loadgen_priority_mix_reaches_the_engine() {
    let (engine, join, metas) = sum_engine(1, 1, 0, 4, 0, 1);
    let (addr, server) = spawn_http(engine, metas);

    let mut cfg = LoadgenConfig::new(addr.to_string());
    cfg.requests = 60;
    cfg.clients = 6;
    cfg.mode = ArrivalMode::Open { rate_rps: 3000.0, dist: loadgen::Dist::Uniform };
    cfg.seed = 13;
    cfg.priorities = loadgen::parse_priority_mix("high=1,low=1").unwrap();
    cfg.shutdown = true;
    let artifact = loadgen::run(&cfg).unwrap();

    let pp = artifact.get("per_priority").unwrap();
    let tier = |t: &str, k: &str| pp.get(t).unwrap().get(k).unwrap().num().unwrap();
    // Both tiers saw traffic (the mix sampler is seeded, so this is
    // deterministic), and the per-tier split covers every request.
    assert!(tier("high", "sent") > 0.0 && tier("low", "sent") > 0.0);
    assert_eq!(tier("high", "sent") + tier("low", "sent") + tier("normal", "sent"), 60.0);
    // High is never *priority*-shed: its threshold equals the queue
    // depth, and the bounded-queue check fires first — so any high
    // refusal is "full", never "shed", whatever the timing.
    assert_eq!(tier("high", "rejected_shed"), 0.0, "high must only ever see 429 full");
    // Tier refusals sum to the overall refusal counters.
    let sum_tiers = |k: &str| tier("low", k) + tier("normal", k) + tier("high", k);
    for k in ["completed", "rejected_full", "rejected_shed", "transport_errors"] {
        assert_eq!(sum_tiers(k), artifact.get(k).unwrap().num().unwrap(), "{k}");
    }

    server.join().unwrap().unwrap();
    join.join().unwrap();
}

#[test]
fn priority_tag_round_trips_to_engine_rejections() {
    // Depth 3 with strict tiering: low sheds at 1 pending, high only at
    // 3. Submit a held request, then a low one -> "shed" on the wire.
    let (engine, join, metas) = sum_engine(1, 2, 300_000, 3, 0, 0);
    let (addr, server) = spawn_http(engine, metas);

    let first = std::thread::spawn(move || {
        post(addr, "/v1/infer", br#"{"model":"sum","id":1,"priority":"high","image":[1.0,2.0]}"#)
    });
    std::thread::sleep(Duration::from_millis(80));
    let low =
        post(addr, "/v1/infer", br#"{"model":"sum","id":2,"priority":"low","image":[1.0,2.0]}"#);
    assert_eq!(low.status, 429);
    assert_eq!(error_code(&low), "shed");
    assert_eq!(first.join().unwrap().status, 200);

    shutdown(addr);
    let net = server.join().unwrap().unwrap();
    let report = join.join().unwrap();
    assert_eq!(net.rejected_shed, 1);
    assert_eq!(report.merged().rejected_shed, 1);
}
