//! End-to-end runtime contract: the PJRT-loaded AOT artifacts must
//! reproduce the JAX-side golden outputs (artifacts/golden/model_io.json),
//! and the coordinator must serve them faithfully.
//!
//! Built only with the `pjrt` cargo feature (see Cargo.toml
//! required-features); skipped with a message when artifacts are missing
//! or when the vendor/xla stub is linked instead of the real crate.

use mamba_x::coordinator::{BatchPolicy, InferenceRequest, Server};
use mamba_x::runtime::{Runtime, Tensor};
use mamba_x::util::Json;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
    }
    ok
}

/// Artifacts present AND a real PJRT runtime linked (not the vendor/xla
/// stub). Returns None with a message otherwise.
fn open_runtime() -> Option<Runtime> {
    if !have_artifacts() {
        return None;
    }
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable ({e})");
            None
        }
    }
}

fn load_model_io() -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<usize>) {
    let j = Json::load("artifacts/golden/model_io.json").expect("model_io");
    let shape = j.get("input_shape").unwrap().usize_vec().unwrap();
    let images: Vec<Vec<f32>> = j
        .get("images")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|v| v.f32_vec().unwrap())
        .collect();
    let logits: Vec<Vec<f32>> = j
        .get("logits")
        .unwrap()
        .arr()
        .unwrap()
        .iter()
        .map(|v| v.f32_vec().unwrap())
        .collect();
    (images, logits, shape)
}

#[test]
fn model_artifact_reproduces_jax_logits() {
    let Some(rt) = open_runtime() else {
        return;
    };
    assert_eq!(rt.platform(), "cpu");
    let exe = rt.load_model().expect("compile model");
    let (images, want_logits, shape) = load_model_io();
    for (img, want) in images.iter().zip(want_logits.iter()) {
        let out = exe
            .run(&[Tensor::new(shape.clone(), img.clone()).unwrap()])
            .expect("execute");
        let got = &out[0];
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() < 1e-3 * (1.0 + w.abs()),
                "logit[{i}]: got {g}, want {w}"
            );
        }
        // Classification agreement (the property that matters downstream).
        let argmax = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
        };
        assert_eq!(argmax(got), argmax(want));
    }
}

#[test]
fn scan_artifact_runs_at_tiny_shape() {
    let Some(rt) = open_runtime() else {
        return;
    };
    let meta = rt.manifest.scan.get("micro").expect("micro scan").clone();
    let exe = rt.load(&meta.file).expect("compile scan");
    let n: usize = meta.shape.iter().product();
    // dA in (0,1], dBu small: the scan of ones/halves has a closed form
    // per lane: state_k = sum_{i<=k} 0.5^(k-i) -> 2 - 0.5^k.
    let d_a = Tensor::new(meta.shape.clone(), vec![0.5; n]).unwrap();
    let d_bu = Tensor::new(meta.shape.clone(), vec![1.0; n]).unwrap();
    let out = exe.run(&[d_a, d_bu]).expect("execute scan");
    let states = &out[0];
    assert_eq!(states.len(), n);
    let (l, rest) = (meta.shape[0], meta.shape[1] * meta.shape[2]);
    for k in 0..l.min(12) {
        let want = 2.0 - 0.5f32.powi(k as i32);
        let got = states[k * rest]; // lane (0,0) at step k
        assert!((got - want).abs() < 1e-4, "step {k}: got {got} want {want}");
    }
}

#[test]
fn coordinator_serves_golden_images() {
    if open_runtime().is_none() {
        return;
    }
    let (images, want_logits, shape) = load_model_io();
    let server = Server::new(BatchPolicy { max_batch: 4, max_wait_us: 500 });
    let (handle, join) = server.spawn(move || {
        let rt = Runtime::new("artifacts")?;
        rt.load_model()
    });
    // Submit each golden image a few times from two client threads.
    let mut clients = Vec::new();
    for t in 0..2u64 {
        let h = handle.clone();
        let images = images.clone();
        let want = want_logits.clone();
        let shape = shape.clone();
        clients.push(std::thread::spawn(move || {
            for rep in 0..3u64 {
                for (i, img) in images.iter().enumerate() {
                    let req = InferenceRequest {
                        id: t * 1000 + rep * 10 + i as u64,
                        image: Tensor::new(shape.clone(), img.clone()).unwrap(),
                    };
                    let resp = h.infer(req).expect("infer");
                    let w = &want[i];
                    for (g, ww) in resp.logits.iter().zip(w.iter()) {
                        assert!((g - ww).abs() < 1e-3 * (1.0 + ww.abs()));
                    }
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    drop(handle);
    let metrics = join.join().expect("server ok");
    assert_eq!(metrics.count(), 2 * 3 * 2);
    assert!(metrics.percentile_us(99.0) > 0);
    assert!(metrics.batches >= 1);
}
