//! Properties of the static scan calibration subsystem (hand-rolled
//! harness: proptest is unavailable offline; `Pcg` provides deterministic
//! shrink-free random cases).
//!
//! The contract under test:
//!
//! * the batch-fused scan is bitwise identical to per-item scans under
//!   any shared (static) scales — fusion is pure layout, never numerics;
//! * a table calibrated on exactly the inputs being quantized reproduces
//!   the dynamic per-invocation path bit-for-bit (scales, INT8 streams,
//!   scan states, logits) — the dynamic path is the oracle;
//! * batch composition stays invisible under a static table, end to end
//!   through `NativeBackend::infer_batch`;
//! * the versioned `CalibTable` artifact round-trips exactly and the
//!   loader rejects foreign/future formats and mismatched models.

use std::path::PathBuf;
use std::sync::Arc;

use mamba_x::config::{MambaXConfig, VimModel};
use mamba_x::quant::{
    channel_abs_max, quantize_scan_inputs, quantize_scan_inputs_static, scale_for, spe_scan_int,
    spe_scan_int_batch_fused, CalibBuilder, CalibTable, CALIB_VERSION,
};
use mamba_x::runtime::native::synthetic_image;
use mamba_x::runtime::{InferenceBackend, NativeBackend, Tensor};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::Pcg;
use mamba_x::vision::{ForwardConfig, ScanExec, VimWeights};

/// PROPERTY: with one shared `shift` vector, the batch-fused scan equals
/// per-item scans bit-for-bit across random (B, L, H, N) shapes —
/// including shapes that cross the auto-threading threshold only when
/// fused.
#[test]
fn prop_batch_fused_scan_matches_per_item() {
    let mut rng = Pcg::new(0xCA11B);
    for case in 0..40 {
        let b = rng.usize_in(1, 7);
        let l = rng.usize_in(1, 40);
        let h = rng.usize_in(1, 10);
        let n = rng.usize_in(1, 6);
        let per = l * h * n;
        let p: Vec<i64> = (0..b * per).map(|_| rng.int8()).collect();
        let q: Vec<i64> = (0..b * per).map(|_| rng.int8()).collect();
        let shift: Vec<i32> = (0..h).map(|_| rng.usize_in(0, 12) as i32).collect();
        let fused = spe_scan_int_batch_fused(&p, &q, &shift, b, l, h, n);
        for item in 0..b {
            let span = item * per..(item + 1) * per;
            let want = spe_scan_int(&p[span.clone()], &q[span.clone()], &shift, l, h, n);
            assert_eq!(&fused[span], want.as_slice(), "case {case} item {item}");
        }
    }
    // Large fused shape: 6 * 80 * 40 * 16 = 307k lanes-steps, well past
    // the threading threshold while one item (51k) stays below it.
    let (b, l, h, n) = (6usize, 80usize, 40usize, 16usize);
    let per = l * h * n;
    let p: Vec<i64> = (0..b * per).map(|_| rng.int8()).collect();
    let q: Vec<i64> = (0..b * per).map(|_| rng.int8()).collect();
    let shift: Vec<i32> = (0..h).map(|_| rng.usize_in(0, 12) as i32).collect();
    let fused = spe_scan_int_batch_fused(&p, &q, &shift, b, l, h, n);
    for item in 0..b {
        let span = item * per..(item + 1) * per;
        let want = spe_scan_int(&p[span.clone()], &q[span.clone()], &shift, l, h, n);
        assert_eq!(&fused[span], want.as_slice(), "large case item {item}");
    }
}

/// PROPERTY: a table built from exactly one scan invocation's streams
/// (max-abs, percentile 1.0) reproduces the dynamic quantizer bit-for-bit
/// at the kernel level: same scales, same INT8 (P, Q), same scan states.
#[test]
fn prop_table_from_own_inputs_matches_dynamic_quantization() {
    let mut rng = Pcg::new(0x57A71C);
    for case in 0..30 {
        let l = rng.usize_in(1, 24);
        let h = rng.usize_in(1, 8);
        let n = rng.usize_in(1, 5);
        let total = l * h * n;
        let da: Vec<f32> = (0..total).map(|_| rng.f32_in(0.0, 1.0)).collect();
        let dbu: Vec<f32> = (0..total).map(|_| rng.f32_in(-1.5, 1.5)).collect();
        let (p, q, scales) = quantize_scan_inputs(&da, &dbu, l, h, n);
        let mut builder = CalibBuilder::new(1, h);
        builder.record(0, channel_abs_max(&da, l, h, n), channel_abs_max(&dbu, l, h, n));
        let table = builder.finalize("kernel", 1.0).unwrap();
        let site = table.site(0);
        assert_eq!(site.shift, scales.shift, "case {case}: shifts");
        assert_eq!(site.sq, scales.sq, "case {case}: sq scales");
        let (ps, qs) = quantize_scan_inputs_static(&da, &dbu, l, h, n, &site.sa_eff, &site.sq);
        assert_eq!(ps, p, "case {case}: P stream");
        assert_eq!(qs, q, "case {case}: Q stream");
        assert_eq!(
            spe_scan_int_batch_fused(&ps, &qs, &site.shift, 1, l, h, n),
            spe_scan_int(&p, &q, &scales.shift, l, h, n),
            "case {case}: scan states"
        );
    }
}

/// Small-but-real model so forward-pass cases stay fast in debug builds
/// (mirrors `rust/tests/hotpath_props.rs::prop_cfg`).
fn prop_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

fn rand_image(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..len).map(|_| rng.f32_in(-1.0, 1.0)).collect()
}

/// Committed golden fixture, anchored to the manifest dir so the test
/// binary runs from any cwd (same convention as `quant_golden.rs`).
fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/calib_v1.json")
}

/// PROPERTY: a table calibrated on a single image makes the static
/// (batch-fused) forward bitwise equal to the dynamic forward on that
/// image — the whole pipeline, not just the kernel.
#[test]
fn prop_calibrated_single_image_forward_matches_dynamic_bitwise() {
    let cfg = prop_cfg();
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    for case in 0..8u64 {
        let weights = VimWeights::init(&cfg, 900 + case);
        let img = rand_image(7000 + case, cfg.input_len());
        let table = weights.calibrate(&tables, &scan, &[img.as_slice()], 1.0).unwrap();
        table.validate("prop", cfg.model.n_blocks, cfg.model.d_inner()).unwrap();
        assert_eq!(table.samples, 1);
        let mut exec = ScanExec::Static(&table);
        let calibrated = weights.forward_batch_ex(&tables, &scan, &[img.as_slice()], &mut exec);
        let dynamic = weights.forward(&tables, &scan, &img);
        assert_eq!(calibrated, vec![dynamic], "case {case}");
    }
}

/// PROPERTY: under one static table, batch composition is invisible —
/// the fused batched forward equals per-item static forwards bitwise,
/// across random batch sizes, scan schedules and calibration sets.
#[test]
fn prop_static_table_batch_fusion_is_invisible() {
    let cfg = prop_cfg();
    let tables = SfuTables::fitted();
    let mut rng = Pcg::new(0xBF5);
    for case in 0..8u64 {
        let weights = VimWeights::init(&cfg, 40 + case);
        let scan = MambaXConfig {
            chunk: 1usize << rng.usize_in(2, 6),
            n_ssa: rng.usize_in(1, 8),
            ..MambaXConfig::default()
        };
        let n_calib = rng.usize_in(1, 5);
        let calib_imgs: Vec<Vec<f32>> =
            (0..n_calib).map(|i| rand_image(case * 50 + i as u64, cfg.input_len())).collect();
        let calib_refs: Vec<&[f32]> = calib_imgs.iter().map(|v| v.as_slice()).collect();
        let table = weights.calibrate(&tables, &scan, &calib_refs, 1.0).unwrap();
        // Serve a *different* stream than was calibrated on: out-of-range
        // values saturate, but fusion must still be invisible.
        let b = rng.usize_in(1, 6);
        let imgs: Vec<Vec<f32>> =
            (0..b).map(|i| rand_image(9000 + case * 10 + i as u64, cfg.input_len())).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let mut exec = ScanExec::Static(&table);
        let batched = weights.forward_batch_ex(&tables, &scan, &refs, &mut exec);
        assert_eq!(batched.len(), b);
        for (i, img) in refs.iter().enumerate() {
            let mut exec1 = ScanExec::Static(&table);
            let single =
                weights.forward_batch_ex(&tables, &scan, std::slice::from_ref(img), &mut exec1);
            assert_eq!(batched[i], single[0], "case {case} img {i}: fusion leaked");
        }
    }
}

/// The end-to-end serving surface: `NativeBackend` with a loaded table
/// fuses batches yet stays per-item bit-identical to `infer`, and a
/// single-image calibration reproduces the uncalibrated backend exactly.
#[test]
fn native_backend_with_calib_is_batch_invariant() {
    let cfg = ForwardConfig::micro();
    let seed = 11u64;
    let imgs: Vec<Vec<f32>> =
        (0..5).map(|id| synthetic_image(seed, id, cfg.input_len())).collect();
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    // Same (cfg, seed) => NativeBackend and this VimWeights agree.
    let weights = VimWeights::init(&cfg, seed);
    let table = Arc::new(
        weights.calibrate(&SfuTables::fitted(), &MambaXConfig::default(), &refs, 1.0).unwrap(),
    );
    let mut calibrated = NativeBackend::new(&cfg, seed).with_calib(Arc::clone(&table)).unwrap();
    assert!(calibrated.calib().is_some());
    let tensors: Vec<Tensor> = imgs
        .iter()
        .map(|v| Tensor::new(cfg.input_shape(), v.clone()).unwrap())
        .collect();
    let tensor_refs: Vec<&Tensor> = tensors.iter().collect();
    let batch = calibrated.infer_batch(&tensor_refs);
    assert_eq!(batch.len(), tensors.len());
    for (i, t) in tensors.iter().enumerate() {
        let single = calibrated.infer(t).unwrap();
        assert_eq!(batch[i].as_ref().unwrap(), &single, "slot {i}: fusion leaked");
    }
    // Calibrating on exactly one image reproduces the dynamic backend on
    // that image, bit for bit.
    let one = Arc::new(
        weights
            .calibrate(&SfuTables::fitted(), &MambaXConfig::default(), &refs[..1], 1.0)
            .unwrap(),
    );
    let mut static_one = NativeBackend::new(&cfg, seed).with_calib(one).unwrap();
    let mut dynamic = NativeBackend::new(&cfg, seed);
    assert_eq!(
        static_one.infer(&tensors[0]).unwrap(),
        dynamic.infer(&tensors[0]).unwrap(),
        "single-image calibration must reproduce the dynamic path"
    );
    // A bad slot fails alone; the rest still fuse.
    let bad = Tensor::zeros(vec![2, 2, 1]);
    let mixed: Vec<&Tensor> = vec![&tensors[0], &bad, &tensors[1]];
    let results = calibrated.infer_batch(&mixed);
    assert!(results[0].is_ok() && results[1].is_err() && results[2].is_ok());
}

/// The table artifact must refuse models it was not calibrated for.
#[test]
fn native_backend_rejects_mismatched_table() {
    let cfg = prop_cfg();
    let weights = VimWeights::init(&cfg, 3);
    let img = rand_image(1, cfg.input_len());
    let table = weights
        .calibrate(&SfuTables::fitted(), &MambaXConfig::default(), &[img.as_slice()], 1.0)
        .unwrap();
    // "prop" table vs the micro model: name (and geometry) mismatch.
    assert!(NativeBackend::micro(1).with_calib(Arc::new(table)).is_err());
}

/// PROPERTY: `CalibTable` serialize -> deserialize round-trips exactly
/// (f32 ranges are stored as IEEE-754 bit patterns).
#[test]
fn prop_calib_table_file_roundtrip_is_exact() {
    let mut rng = Pcg::new(0x10AD);
    for case in 0..10 {
        let n_sites = 2 * rng.usize_in(1, 3);
        let channels = rng.usize_in(1, 9);
        let items = rng.usize_in(1, 6);
        let mut builder = CalibBuilder::new(n_sites, channels);
        for _ in 0..items {
            for site in 0..n_sites {
                let da: Vec<f32> = (0..channels).map(|_| rng.f32_in(1e-6, 4.0)).collect();
                let dbu: Vec<f32> = (0..channels).map(|_| rng.f32_in(1e-6, 4.0)).collect();
                builder.record(site, da, dbu);
            }
        }
        let percentile = rng.f32_in(0.1, 1.0);
        let table = builder.finalize("roundtrip", percentile).unwrap();
        let path = std::env::temp_dir()
            .join(format!("mamba_x_calib_props_{}_{case}.json", std::process::id()));
        table.save(&path).unwrap();
        let loaded = CalibTable::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded, table, "case {case}: round-trip drifted");
    }
}

/// Golden artifact: the committed v1 fixture parses, carries the version
/// field, and re-derives the expected scales from its bit-exact ranges.
#[test]
fn golden_calib_artifact_v1() {
    let table = CalibTable::load(golden_path()).unwrap();
    assert_eq!(table.version, CALIB_VERSION);
    assert_eq!(table.model, "golden");
    assert_eq!(table.samples, 4);
    assert_eq!(table.percentile, 1.0);
    assert_eq!(table.sites.len(), 2);
    let fwd = table.site(0);
    assert_eq!((fwd.block, fwd.dir), (0, 0));
    assert_eq!(fwd.da_max, vec![0.8, 1.6]);
    assert_eq!(fwd.dbu_max, vec![0.5, 0.25]);
    assert_eq!(fwd.shift, vec![7, 6]);
    // pow2-rounded dA scales are exact powers of two.
    assert_eq!(fwd.sa_eff, vec![0.0078125, 0.015625]);
    // sq re-derives through the same f32 arithmetic as the quantizer.
    assert_eq!(fwd.sq, vec![scale_for(0.5, 8), scale_for(0.25, 8)]);
    let bwd = table.site(1);
    assert_eq!((bwd.block, bwd.dir), (0, 1));
    assert_eq!(bwd.shift, vec![8, 11]);
    assert_eq!(bwd.sa_eff, vec![0.00390625, 0.00048828125]);
}

/// The loader is a format gate: future versions and foreign files fail
/// with a clear error instead of being misread.
#[test]
fn calib_loader_rejects_future_versions() {
    let good = std::fs::read_to_string(golden_path()).unwrap();
    let future = good.replace("\"version\": 1", "\"version\": 99");
    assert_ne!(good, future, "fixture must contain the version field");
    let path = std::env::temp_dir()
        .join(format!("mamba_x_calib_future_{}.json", std::process::id()));
    std::fs::write(&path, future).unwrap();
    let err = CalibTable::load(&path).unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(format!("{err}").contains("version 99"), "unhelpful error: {err}");
}
