//! Worker-pool properties: request-count conservation across the
//! shutdown drain (every accepted request answered exactly once),
//! percentile monotonicity of merged metrics, bounded-queue rejection
//! behavior, and the v1 admission policy — shed decisions respect
//! priority order, are monotone in the deadline, and an accepted
//! request is never shed later, even under deadline churn.
//!
//! Hand-rolled Pcg harness, 100+ randomized cases where cheap.

use std::time::Duration;

use anyhow::Result;
use mamba_x::coordinator::{
    admission_check, AdmissionDeny, BatchPolicy, EngineBuilder, EngineError, InferenceRequest,
    Metrics, Priority, Request, Server,
};
use mamba_x::runtime::{InferenceBackend, ModelSpec, Tensor};
use mamba_x::util::Pcg;

/// Deterministic synthetic backend with a configurable service time.
struct Echo {
    delay: Duration,
}

impl InferenceBackend for Echo {
    fn name(&self) -> &'static str {
        "echo"
    }

    fn infer(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(vec![image.data.iter().sum::<f32>(), image.data[0]])
    }
}

fn req(id: u64) -> InferenceRequest {
    let v = id as f32;
    InferenceRequest { id, image: Tensor::new(vec![3], vec![v, v + 1.0, v + 2.0]).unwrap() }
}

/// PROPERTY: across shutdown drain, every accepted request is answered
/// exactly once (no drops, no duplicates), for any pool geometry.
#[test]
fn prop_shutdown_drain_conserves_requests() {
    let mut rng = Pcg::new(0xD7A1);
    for case in 0..25 {
        let workers = rng.usize_in(1, 4);
        let max_batch = rng.usize_in(1, 6);
        let n_requests = rng.usize_in(5, 40);
        let delay = Duration::from_micros(rng.usize_in(0, 800) as u64);
        let server = Server::new(BatchPolicy {
            max_batch,
            max_wait_us: rng.usize_in(0, 500) as u64,
        })
        .queue_depth(n_requests);
        let (handle, join) = server.spawn_pool(workers, move |_w| Ok(Echo { delay }));
        let waiters: Vec<_> = (0..n_requests as u64)
            .map(|id| handle.submit(req(id)).expect("queue_depth == n_requests"))
            .collect();
        // Drop the only handle while requests are still in flight: the
        // pool must drain, not drop.
        drop(handle);
        let mut ids: Vec<u64> = waiters
            .into_iter()
            .map(|w| w.wait().expect("drained request must succeed").id)
            .collect();
        ids.sort_unstable();
        let want: Vec<u64> = (0..n_requests as u64).collect();
        assert_eq!(ids, want, "case {case}: each request answered exactly once");
        let metrics = join.join().unwrap();
        assert_eq!(metrics.count(), n_requests, "case {case}");
        assert_eq!(metrics.rejected(), 0, "case {case}");
        assert!(metrics.batch_items as usize == n_requests, "case {case}");
    }
}

/// PROPERTY: merged pool metrics keep percentiles monotone:
/// p50 <= p95 <= p99 <= max sample.
#[test]
fn prop_merged_percentiles_monotone() {
    let mut rng = Pcg::new(0x9E0);
    for _case in 0..100 {
        let mut merged = Metrics::default();
        let mut max_sample = 0u64;
        for _worker in 0..rng.usize_in(1, 5) {
            let mut m = Metrics::default();
            for _ in 0..rng.usize_in(1, 50) {
                let lat = rng.usize_in(1, 1_000_000) as u64;
                max_sample = max_sample.max(lat);
                m.record_request(lat, rng.usize_in(0, 1000) as u64);
            }
            merged.merge(&m);
        }
        let (p50, p95, p99) = (
            merged.percentile_us(50.0),
            merged.percentile_us(95.0),
            merged.percentile_us(99.0),
        );
        assert!(p50 <= p95, "p50 {p50} > p95 {p95}");
        assert!(p95 <= p99, "p95 {p95} > p99 {p99}");
        assert!(p99 <= max_sample, "p99 {p99} > max {max_sample}");
    }
}

/// Live-pool variant: percentiles from an actual multi-worker run.
#[test]
fn pool_metrics_percentiles_monotone_live() {
    let server = Server::new(BatchPolicy { max_batch: 4, max_wait_us: 200 });
    let (handle, join) =
        server.spawn_pool(3, |_w| Ok(Echo { delay: Duration::from_micros(300) }));
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let h = handle.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..15u64 {
                h.infer(req(c * 100 + i)).unwrap();
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    drop(handle);
    let m = join.join().unwrap();
    assert_eq!(m.count(), 45);
    assert!(m.percentile_us(50.0) <= m.percentile_us(95.0));
    assert!(m.percentile_us(95.0) <= m.percentile_us(99.0));
    assert!(m.percentile_us(99.0) > 0);
    assert!(m.throughput_rps() > 0.0);
}

/// Bounded queue: admission beyond `queue_depth` is refused immediately,
/// every accepted request still completes, and the books balance:
/// accepted + rejected == submitted.
#[test]
fn bounded_queue_rejects_and_conserves() {
    let depth = 4usize;
    let submitted = 60usize;
    let server = Server::new(BatchPolicy { max_batch: 1, max_wait_us: 0 }).queue_depth(depth);
    let (handle, join) =
        server.spawn_pool(1, |_w| Ok(Echo { delay: Duration::from_millis(3) }));
    let mut waiters = Vec::new();
    let mut rejected = 0usize;
    for id in 0..submitted as u64 {
        match handle.submit(req(id)) {
            Ok(w) => waiters.push(w),
            Err(_) => rejected += 1,
        }
    }
    // One slow worker, 3ms/job, 60 near-instant submits, queue bound 4:
    // the queue must have filled at least once.
    assert!(rejected > 0, "expected backpressure rejections");
    let accepted = waiters.len();
    assert_eq!(accepted + rejected, submitted);
    for w in waiters {
        assert!(w.wait().is_ok(), "accepted requests must complete");
    }
    drop(handle);
    let metrics = join.join().unwrap();
    assert_eq!(metrics.count(), accepted);
    assert_eq!(metrics.rejected() as usize, rejected);
    // v0 handles submit at High priority with no deadline: every
    // rejection is bounded-queue backpressure, never load shedding.
    assert_eq!(metrics.rejected_shed, 0);
    // max_batch == 1: one request per batch, conservation again.
    assert_eq!(metrics.batches as usize, accepted);
}

/// Zero-depth-adjacent edge: queue_depth clamps to >= 1 and still serves.
#[test]
fn queue_depth_floor_still_serves() {
    let server = Server::new(BatchPolicy::default()).queue_depth(0);
    let (handle, join) = server.spawn_pool(2, |_w| Ok(Echo { delay: Duration::ZERO }));
    let resp = handle.infer(req(1)).unwrap();
    assert_eq!(resp.id, 1);
    drop(handle);
    assert!(join.join().unwrap().count() >= 1);
}

/// PROPERTY: the pure admission decision respects priority order and is
/// monotone in the deadline — at an identical queue state, raising the
/// priority or loosening the deadline never turns an admit into a
/// refusal; and the refusal reason is Full exactly when the queue is at
/// depth.
#[test]
fn prop_admission_monotone_in_priority_and_deadline() {
    let mut rng = Pcg::new(0xAD15);
    // Random geometry for breadth, PLUS every depth in 1..=8 exhaustively
    // (ISSUE 6: the small depths are where tier collapse used to hide).
    let mut geometries: Vec<(usize, usize)> = Vec::new();
    for depth in 1..=8usize {
        for pending in 0..=depth + 2 {
            geometries.push((depth, pending));
        }
    }
    for _ in 0..300 {
        let depth = rng.usize_in(1, 64);
        geometries.push((depth, rng.usize_in(0, depth + 8)));
    }
    for (case, &(depth, pending)) in geometries.iter().enumerate() {
        let projected = rng.usize_in(0, 5_000) as u64;
        let deadline = match rng.usize_in(0, 2) {
            0 => None,
            _ => Some(rng.usize_in(0, 5_000) as u64),
        };
        let verdicts: Vec<_> = Priority::ALL
            .iter()
            .map(|&p| admission_check(pending, depth, p, deadline, projected))
            .collect();
        // Priority order: once a priority is admitted, every higher one is.
        for pair in verdicts.windows(2) {
            assert!(
                !(pair[0].is_ok() && pair[1].is_err()),
                "case {case}: admitted at lower priority but shed at higher \
                 (pending={pending} depth={depth} deadline={deadline:?} projected={projected})"
            );
        }
        for (p, verdict) in Priority::ALL.iter().zip(&verdicts) {
            match verdict {
                Err(AdmissionDeny::QueueFull { .. }) => {
                    assert!(pending >= depth, "case {case}: Full only at depth")
                }
                Err(_) => assert!(pending < depth, "case {case}: shed implies not full"),
                Ok(()) => {
                    // Deadline monotonicity: any looser deadline (or none)
                    // is admitted at the same state.
                    if let Some(d) = deadline {
                        for extra in [1u64, 1000] {
                            assert!(
                                admission_check(
                                    pending,
                                    depth,
                                    *p,
                                    Some(d.saturating_add(extra)),
                                    projected
                                )
                                .is_ok(),
                                "case {case}: loosening the deadline revoked admission"
                            );
                        }
                    }
                    assert!(
                        admission_check(pending, depth, *p, None, projected).is_ok(),
                        "case {case}: dropping the deadline revoked admission"
                    );
                }
            }
        }
    }
    // Strict-tiering consequence at every small depth >= 3 (regression,
    // ISSUE 6): some backlog admits Normal while shedding Low, and some
    // backlog admits High while shedding Normal.
    for depth in 3..=8usize {
        let low_t = Priority::Low.shed_threshold(depth);
        let normal_t = Priority::Normal.shed_threshold(depth);
        assert!(admission_check(low_t, depth, Priority::Low, None, 0).is_err(), "depth {depth}");
        assert!(admission_check(low_t, depth, Priority::Normal, None, 0).is_ok(), "depth {depth}");
        assert!(
            admission_check(normal_t, depth, Priority::Normal, None, 0).is_err(),
            "depth {depth}"
        );
        assert!(
            admission_check(normal_t, depth, Priority::High, None, 0).is_ok(),
            "depth {depth}"
        );
    }
}

/// PROPERTY: under deadline churn — random priorities, deadlines and a
/// live backlog — every ACCEPTED request is ANSWERED exactly once:
/// either it completes, or (since deadlines are enforced at dequeue) it
/// fails with a typed `DeadlineExceeded` — never silently shed, never
/// dropped, never a generic error. Every refusal is typed, and the
/// books balance: completed + deadline_exceeded + rejected ==
/// submitted, with the per-reason report counters matching what clients
/// observed.
#[test]
fn prop_accepted_never_shed_under_deadline_churn() {
    let mut rng = Pcg::new(0xC0F3);
    for case in 0..20 {
        let workers = rng.usize_in(1, 3);
        let max_batch = rng.usize_in(1, 4);
        let depth = rng.usize_in(2, 10);
        let hint_us = rng.usize_in(0, 4_000) as u64;
        let delay = Duration::from_micros(rng.usize_in(0, 600) as u64);
        let n_requests = rng.usize_in(10, 40);
        let spec = ModelSpec::new(
            "echo",
            std::sync::Arc::new(move |_w| {
                Ok(Box::new(Echo { delay }) as Box<dyn InferenceBackend>)
            }),
        )
        .service_hint_us(hint_us);
        let (engine, join) = EngineBuilder::new()
            .workers(workers)
            .policy(BatchPolicy { max_batch, max_wait_us: rng.usize_in(0, 400) as u64 })
            .queue_depth(depth)
            .register(spec)
            .unwrap()
            .build()
            .unwrap();
        let mut waiters = Vec::new();
        let (mut seen_full, mut seen_shed) = (0u64, 0u64);
        for id in 0..n_requests as u64 {
            let mut request = Request::new("echo", id, req(id).image)
                .priority(Priority::ALL[rng.usize_in(0, 2)]);
            if rng.usize_in(0, 2) > 0 {
                request = request.deadline_us(rng.usize_in(0, 3_000) as u64);
            }
            match engine.submit(request) {
                Ok(w) => waiters.push((id, w)),
                Err(EngineError::Rejected { reason, .. }) => match reason {
                    mamba_x::coordinator::RejectReason::Full => seen_full += 1,
                    mamba_x::coordinator::RejectReason::Shed => seen_shed += 1,
                    mamba_x::coordinator::RejectReason::UnknownModel => {
                        panic!("case {case}: model is registered")
                    }
                    mamba_x::coordinator::RejectReason::ClientQuota => {
                        panic!("case {case}: no quota configured")
                    }
                    mamba_x::coordinator::RejectReason::BreakerOpen => {
                        panic!("case {case}: no backend failures, breaker must stay closed")
                    }
                },
                Err(e) => panic!("case {case}: untyped refusal {e}"),
            }
        }
        let accepted = waiters.len();
        let mut seen_deadline = 0u64;
        let mut ids: Vec<u64> = Vec::new();
        for (id, w) in waiters {
            match w.wait() {
                Ok(resp) => {
                    assert_eq!(resp.id, id, "case {case}");
                    ids.push(resp.id);
                }
                Err(EngineError::DeadlineExceeded { model, .. }) => {
                    assert_eq!(model, "echo", "case {case}");
                    seen_deadline += 1;
                }
                Err(e) => panic!("case {case}: accepted request {id} got untyped failure {e}"),
            }
        }
        let completed = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), completed, "case {case}: exactly-once");
        assert_eq!(
            completed as u64 + seen_deadline,
            accepted as u64,
            "case {case}: every accepted request answered"
        );
        drop(engine);
        let report = join.join().unwrap();
        let m = &report.model("echo").expect("registered model reported").metrics;
        assert_eq!(m.count(), completed, "case {case}");
        assert_eq!(m.deadline_exceeded, seen_deadline, "case {case}");
        assert_eq!(m.backend_failed, 0, "case {case}");
        assert_eq!(
            accepted as u64 + seen_full + seen_shed,
            n_requests as u64,
            "case {case}: conservation"
        );
        assert_eq!(m.rejected_full, seen_full, "case {case}");
        assert_eq!(m.rejected_shed, seen_shed, "case {case}");
        assert_eq!(report.rejected_unknown_model, 0, "case {case}");
    }
}
