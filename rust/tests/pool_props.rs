//! Worker-pool properties: request-count conservation across the
//! shutdown drain (every accepted request answered exactly once),
//! percentile monotonicity of merged metrics, and bounded-queue
//! rejection behavior.
//!
//! Hand-rolled Pcg harness, 100+ randomized cases where cheap.

use std::time::Duration;

use anyhow::Result;
use mamba_x::coordinator::{BatchPolicy, InferenceRequest, Metrics, Server};
use mamba_x::runtime::{InferenceBackend, Tensor};
use mamba_x::util::Pcg;

/// Deterministic synthetic backend with a configurable service time.
struct Echo {
    delay: Duration,
}

impl InferenceBackend for Echo {
    fn name(&self) -> &'static str {
        "echo"
    }

    fn infer(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(vec![image.data.iter().sum::<f32>(), image.data[0]])
    }
}

fn req(id: u64) -> InferenceRequest {
    let v = id as f32;
    InferenceRequest { id, image: Tensor::new(vec![3], vec![v, v + 1.0, v + 2.0]).unwrap() }
}

/// PROPERTY: across shutdown drain, every accepted request is answered
/// exactly once (no drops, no duplicates), for any pool geometry.
#[test]
fn prop_shutdown_drain_conserves_requests() {
    let mut rng = Pcg::new(0xD7A1);
    for case in 0..25 {
        let workers = rng.usize_in(1, 4);
        let max_batch = rng.usize_in(1, 6);
        let n_requests = rng.usize_in(5, 40);
        let delay = Duration::from_micros(rng.usize_in(0, 800) as u64);
        let server = Server::new(BatchPolicy {
            max_batch,
            max_wait_us: rng.usize_in(0, 500) as u64,
        })
        .queue_depth(n_requests);
        let (handle, join) = server.spawn_pool(workers, move |_w| Ok(Echo { delay }));
        let waiters: Vec<_> = (0..n_requests as u64)
            .map(|id| handle.submit(req(id)).expect("queue_depth == n_requests"))
            .collect();
        // Drop the only handle while requests are still in flight: the
        // pool must drain, not drop.
        drop(handle);
        let mut ids: Vec<u64> = waiters
            .into_iter()
            .map(|w| w.wait().expect("drained request must succeed").id)
            .collect();
        ids.sort_unstable();
        let want: Vec<u64> = (0..n_requests as u64).collect();
        assert_eq!(ids, want, "case {case}: each request answered exactly once");
        let metrics = join.join().unwrap();
        assert_eq!(metrics.count(), n_requests, "case {case}");
        assert_eq!(metrics.rejected, 0, "case {case}");
        assert!(metrics.batch_items as usize == n_requests, "case {case}");
    }
}

/// PROPERTY: merged pool metrics keep percentiles monotone:
/// p50 <= p95 <= p99 <= max sample.
#[test]
fn prop_merged_percentiles_monotone() {
    let mut rng = Pcg::new(0x9E0);
    for _case in 0..100 {
        let mut merged = Metrics::default();
        let mut max_sample = 0u64;
        for _worker in 0..rng.usize_in(1, 5) {
            let mut m = Metrics::default();
            for _ in 0..rng.usize_in(1, 50) {
                let lat = rng.usize_in(1, 1_000_000) as u64;
                max_sample = max_sample.max(lat);
                m.record_request(lat, rng.usize_in(0, 1000) as u64);
            }
            merged.merge(&m);
        }
        let (p50, p95, p99) = (
            merged.percentile_us(50.0),
            merged.percentile_us(95.0),
            merged.percentile_us(99.0),
        );
        assert!(p50 <= p95, "p50 {p50} > p95 {p95}");
        assert!(p95 <= p99, "p95 {p95} > p99 {p99}");
        assert!(p99 <= max_sample, "p99 {p99} > max {max_sample}");
    }
}

/// Live-pool variant: percentiles from an actual multi-worker run.
#[test]
fn pool_metrics_percentiles_monotone_live() {
    let server = Server::new(BatchPolicy { max_batch: 4, max_wait_us: 200 });
    let (handle, join) =
        server.spawn_pool(3, |_w| Ok(Echo { delay: Duration::from_micros(300) }));
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let h = handle.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..15u64 {
                h.infer(req(c * 100 + i)).unwrap();
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    drop(handle);
    let m = join.join().unwrap();
    assert_eq!(m.count(), 45);
    assert!(m.percentile_us(50.0) <= m.percentile_us(95.0));
    assert!(m.percentile_us(95.0) <= m.percentile_us(99.0));
    assert!(m.percentile_us(99.0) > 0);
    assert!(m.throughput_rps() > 0.0);
}

/// Bounded queue: admission beyond `queue_depth` is refused immediately,
/// every accepted request still completes, and the books balance:
/// accepted + rejected == submitted.
#[test]
fn bounded_queue_rejects_and_conserves() {
    let depth = 4usize;
    let submitted = 60usize;
    let server = Server::new(BatchPolicy { max_batch: 1, max_wait_us: 0 }).queue_depth(depth);
    let (handle, join) =
        server.spawn_pool(1, |_w| Ok(Echo { delay: Duration::from_millis(3) }));
    let mut waiters = Vec::new();
    let mut rejected = 0usize;
    for id in 0..submitted as u64 {
        match handle.submit(req(id)) {
            Ok(w) => waiters.push(w),
            Err(_) => rejected += 1,
        }
    }
    // One slow worker, 3ms/job, 60 near-instant submits, queue bound 4:
    // the queue must have filled at least once.
    assert!(rejected > 0, "expected backpressure rejections");
    let accepted = waiters.len();
    assert_eq!(accepted + rejected, submitted);
    for w in waiters {
        assert!(w.wait().is_ok(), "accepted requests must complete");
    }
    drop(handle);
    let metrics = join.join().unwrap();
    assert_eq!(metrics.count(), accepted);
    assert_eq!(metrics.rejected as usize, rejected);
    // max_batch == 1: one request per batch, conservation again.
    assert_eq!(metrics.batches as usize, accepted);
}

/// Zero-depth-adjacent edge: queue_depth clamps to >= 1 and still serves.
#[test]
fn queue_depth_floor_still_serves() {
    let server = Server::new(BatchPolicy::default()).queue_depth(0);
    let (handle, join) = server.spawn_pool(2, |_w| Ok(Echo { delay: Duration::ZERO }));
    let resp = handle.infer(req(1)).unwrap();
    assert_eq!(resp.id, 1);
    drop(handle);
    assert!(join.join().unwrap().count() >= 1);
}
