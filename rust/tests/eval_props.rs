//! Accuracy-evaluation subsystem properties (the acceptance gate for
//! the eval harness + drift-gated INT8-activation serving):
//!
//! * same-seed determinism: two independently built eval reports over
//!   the same (seed, samples, weights) dump *byte-identical* JSON — the
//!   contract behind the CI `cmp` determinism gate — while a different
//!   seed produces a different stream;
//! * the f32 oracle is honest: a dense f32 variant served through the
//!   real engine (admission, batching, epoch machinery) is bitwise the
//!   reference oracle, so its agreement floors sit at exactly 1.0;
//! * INT8 activations are gated drift, not silent corruption: over
//!   random model geometries the `"activations": "i8"` forward stays
//!   within a generous relative-error budget of the f32 oracle, is
//!   run-to-run deterministic, and the f32 default stays bitwise the
//!   plain forward on the same INT8-stored weights;
//! * the committed golden eval report fixture decodes pinned
//!   (field-for-field) and re-encodes to a stable fixpoint;
//! * the committed `EVAL_baseline.json` is well-formed and gates:
//!   a perfect report passes it, while foreign formats, future
//!   versions, and missing metrics are refused/failed typed.

use std::path::PathBuf;

use mamba_x::config::{MambaXConfig, VimModel};
use mamba_x::coordinator::{BatchPolicy, EngineBuilder, Request};
use mamba_x::eval::{
    check_eval, oracle_logits, weight_quant_frontier, EvalReport, EvalSet, FrontierSweep,
    ModelEval, EVAL_BASELINE_FORMAT, EVAL_BASELINE_VERSION,
};
use mamba_x::quant::{WeightQuantOpts, WeightQuantPlan};
use mamba_x::runtime::{ModelSource, ModelSpec, NativeBackend, Tensor};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::util::{Json, Pcg};
use mamba_x::vision::{ActMode, ForwardConfig, ScanExec, VimWeights};

/// Small-but-real model (same shape as the other property suites).
fn tiny_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "eval-prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

/// Build a full eval report from scratch — set, oracle, a quantized
/// variant scored against it, and the frontier sweep — with no caching
/// between calls, so equality below is end-to-end determinism.
fn build_report(seed: u64) -> EvalReport {
    let cfg = tiny_cfg();
    let weights = VimWeights::init(&cfg, 19);
    let set = EvalSet::synthetic(seed, 4, cfg.input_len()).unwrap();
    let oracle = oracle_logits(&weights, &set).unwrap();
    let mut q = weights.clone();
    q.apply_weight_quant(&WeightQuantPlan::all_at_percentile(
        &q.weight_quant_candidates(),
        0.999,
    ))
    .unwrap();
    let got = q.forward_batch(&SfuTables::fitted(), &MambaXConfig::default(), &set.refs());
    let mut m = ModelEval::compute("det@w8", "f32", &oracle, &got).unwrap();
    let (f32_eq, stored) = q.weight_bytes();
    m.weight_bytes_f32 = f32_eq as u64;
    m.weight_bytes_stored = stored as u64;
    let points = weight_quant_frontier(&weights, &set, &WeightQuantOpts::default()).unwrap();
    EvalReport {
        seed,
        samples: set.items.len(),
        config: "det".to_string(),
        models: vec![m],
        frontier: vec![FrontierSweep { model: "det@w8".to_string(), points }],
    }
}

/// PROPERTY: identical seeds produce byte-identical report JSON — the
/// whole pipeline (synthetic stream, oracle forward, quantization,
/// metric reduction, frontier sweep, JSON dump) is deterministic, which
/// is exactly what the CI runs `mamba-x eval` twice to `cmp`-verify.
#[test]
fn same_seed_reports_dump_byte_identical() {
    let a = build_report(3).to_json().dump();
    let b = build_report(3).to_json().dump();
    assert_eq!(a, b, "same seed must reproduce the report byte-for-byte");
    let c = build_report(4).to_json().dump();
    assert_ne!(a, c, "different eval seeds must change the report");
    // And the dump round-trips exactly.
    let back = EvalReport::from_json(&Json::parse(&a).unwrap()).unwrap();
    assert_eq!(back.to_json().dump(), a);
}

/// ACCEPTANCE (oracle honesty): a dense f32 variant driven through the
/// serving engine — admission, batching, the epoch machinery — returns
/// logits bitwise identical to [`oracle_logits`], so the committed 1.0
/// agreement floors for `"activations": "f32"` variants are exact, not
/// statistical.
#[test]
fn f32_variant_served_through_engine_is_bitwise_the_oracle() {
    let cfg = tiny_cfg();
    let seed = 23u64;
    let set = EvalSet::synthetic(11, 6, cfg.input_len()).unwrap();
    let oracle = oracle_logits(&VimWeights::init(&cfg, seed), &set).unwrap();

    let source = ModelSource::RandomInit { config: cfg.clone(), seed };
    let spec = ModelSpec::new("eval@f32", NativeBackend::factory(source, None, None).unwrap());
    let (engine, join) = EngineBuilder::new()
        .workers(2)
        .policy(BatchPolicy { max_batch: 4, max_wait_us: 200 })
        .queue_depth(32)
        .register(spec)
        .unwrap()
        .build()
        .unwrap();
    let mut got = Vec::new();
    for (k, item) in set.items.iter().enumerate() {
        let img = Tensor::new(cfg.input_shape(), item.clone()).unwrap();
        got.push(engine.infer(Request::new("eval@f32", k as u64, img)).unwrap().logits);
    }
    drop(engine);
    join.join().unwrap();

    assert_eq!(got, oracle, "engine-served f32 logits are bitwise the reference oracle");
    let m = ModelEval::compute("eval@f32", "f32", &oracle, &got).unwrap();
    assert_eq!(m.top1_agreement, 1.0);
    assert_eq!(m.top5_agreement, 1.0);
    assert_eq!(m.mean_logit_mse, 0.0);
    assert_eq!(m.max_rel_err, 0.0);
}

/// PROPERTY (drift budget): over random model geometries, running INT8
/// activations on INT8-stored weights (the `matmul_i8` hot path) stays
/// within a generous relative-logit-error budget of the f32 oracle and
/// is run-to-run deterministic — while `ActMode::F32` on the *same*
/// quantized weights remains bitwise the plain `forward_batch`, i.e.
/// the default activation mode can never change served bits.
#[test]
fn i8_activation_drift_bounded_over_random_geometries_f32_default_bitwise() {
    let tables = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let mut rng = Pcg::new(0xE7A1_0001);
    for case in 0..4u64 {
        let cfg = ForwardConfig {
            model: VimModel {
                name: "eval-rand",
                d_model: 8 * rng.usize_in(1, 2),
                n_blocks: rng.usize_in(1, 2),
                d_state: 2 * rng.usize_in(1, 2),
                expand: 2,
                conv_k: 4,
                patch: if rng.f64() < 0.5 { 2 } else { 4 },
            },
            img: 8,
            in_ch: 1,
            n_classes: rng.usize_in(4, 8),
        };
        let tag = format!(
            "case {case}: d_model={} n_blocks={} d_state={} patch={} classes={}",
            cfg.model.d_model, cfg.model.n_blocks, cfg.model.d_state, cfg.model.patch, cfg.n_classes
        );
        let weights = VimWeights::init(&cfg, 100 + case);
        let set = EvalSet::synthetic(40 + case, 3, cfg.input_len()).unwrap();
        let oracle = oracle_logits(&weights, &set).unwrap();

        let mut q = weights.clone();
        q.apply_weight_quant(&WeightQuantPlan::all_at_percentile(
            &q.weight_quant_candidates(),
            0.999,
        ))
        .unwrap();

        // The default stays bitwise: ActMode::F32 is plain forward_batch.
        let f32_plain = q.forward_batch(&tables, &scan, &set.refs());
        let f32_act =
            q.forward_batch_act(&tables, &scan, &set.refs(), &mut ScanExec::Dynamic, ActMode::F32);
        assert_eq!(f32_act, f32_plain, "{tag}: f32 activations must not change bits");

        // The i8 hot path engages (different kernel, different bits)...
        let i8_act =
            q.forward_batch_act(&tables, &scan, &set.refs(), &mut ScanExec::Dynamic, ActMode::I8);
        assert_ne!(i8_act, f32_plain, "{tag}: i8 activations must engage the INT8 GEMM");
        // ...deterministically...
        let again =
            q.forward_batch_act(&tables, &scan, &set.refs(), &mut ScanExec::Dynamic, ActMode::I8);
        assert_eq!(i8_act, again, "{tag}: i8 forward must be run-to-run deterministic");

        // ...and within the drift budget of the f32 oracle.
        let m = ModelEval::compute("rand@w8a8", "i8", &oracle, &i8_act).unwrap();
        assert!(m.max_rel_err.is_finite(), "{tag}: rel err must be finite");
        assert!(
            m.max_rel_err < 1.0,
            "{tag}: i8 activation drift {} blew the relative-error budget",
            m.max_rel_err
        );
        assert!((0.0..=1.0).contains(&m.top1_agreement), "{tag}");
        assert!(m.top5_agreement >= m.top1_agreement, "{tag}: top-5 contains top-1");
    }
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/eval_v1.json")
}

/// The committed golden eval report decodes pinned: every field of the
/// fixture is asserted, and decode -> encode is a stable fixpoint (the
/// byte layout of *fresh* dumps is covered by the determinism property;
/// the fixture pins the decode semantics across future format work).
#[test]
fn golden_eval_report_v1_decodes_pinned() {
    let report = EvalReport::load(golden_path()).unwrap();
    assert_eq!(report.seed, 7);
    assert_eq!(report.samples, 4);
    assert_eq!(report.config, "golden-engine.json");
    assert_eq!(report.models.len(), 2);

    let f = &report.models[0];
    assert_eq!(f.name, "golden@f32");
    assert_eq!(f.activations, "f32");
    assert_eq!(f.samples, 4);
    assert_eq!(f.top1_agreement, 1.0);
    assert_eq!(f.top5_agreement, 1.0);
    assert_eq!(f.logit_mse, vec![0.0, 0.0, 0.0]);
    assert_eq!(f.mean_logit_mse, 0.0);
    assert_eq!(f.max_rel_err, 0.0);
    assert_eq!(f.weight_bytes_f32, 4096);
    assert_eq!(f.weight_bytes_stored, 4096);

    let q = &report.models[1];
    assert_eq!(q.name, "golden@w8a8");
    assert_eq!(q.activations, "i8");
    assert_eq!(q.top1_agreement, 0.75);
    assert_eq!(q.logit_mse, vec![0.015625, 0.03125, 0.046875]);
    assert_eq!(q.mean_logit_mse, 0.03125, "dyadic mean is exact in binary");
    assert_eq!(q.max_rel_err, 0.125);
    assert_eq!(q.weight_bytes_stored, 1280);

    assert_eq!(report.frontier.len(), 1);
    let sweep = &report.frontier[0];
    assert_eq!(sweep.model, "golden@w8a8");
    let pcts: Vec<f32> = sweep.points.iter().map(|p| p.percentile).collect();
    assert_eq!(pcts, vec![1.0, 0.999, 0.99], "candidate order is pinned");
    assert!(sweep.points.iter().all(|p| p.weight_bytes_stored < p.weight_bytes_f32));

    // Decode -> encode -> decode is a fixpoint.
    let dump = report.to_json().dump();
    let back = EvalReport::from_json(&Json::parse(&dump).unwrap()).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.to_json().dump(), dump);
}

/// A gate-facing report whose metrics are all perfect for `name`.
fn perfect_eval(name: &str, activations: &str) -> ModelEval {
    ModelEval {
        name: name.to_string(),
        activations: activations.to_string(),
        samples: 8,
        top1_agreement: 1.0,
        top5_agreement: 1.0,
        logit_mse: vec![0.0, 0.0],
        mean_logit_mse: 0.0,
        max_rel_err: 0.0,
        weight_bytes_f32: 1024,
        weight_bytes_stored: 1024,
    }
}

/// ACCEPTANCE (gate wiring): the *committed* `EVAL_baseline.json` is a
/// well-formed current-version baseline that actually gates — a perfect
/// report over the CI variant names passes it, dropping a gated variant
/// fails it — and foreign/future baselines are refused typed before any
/// comparison runs.
#[test]
fn committed_baseline_gates_and_refuses_foreign_or_future() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("EVAL_baseline.json");
    let baseline = Json::load(&path).unwrap();
    assert_eq!(baseline.get("format").unwrap().str().unwrap(), EVAL_BASELINE_FORMAT);
    assert_eq!(
        baseline.get("version").unwrap().num().unwrap() as u32,
        EVAL_BASELINE_VERSION,
        "committed baseline must be the current version"
    );

    // Every gated model name, served perfectly, passes the real floors.
    let report = EvalReport {
        seed: 7,
        samples: 8,
        config: "ci".to_string(),
        models: vec![
            perfect_eval("eval@f32", "f32"),
            perfect_eval("eval@w8", "f32"),
            perfect_eval("eval@w8a8", "i8"),
        ],
        frontier: Vec::new(),
    };
    let current = report.to_json();
    let gate = check_eval(&current, &baseline, None).unwrap();
    assert!(gate.passed(), "perfect report fails committed baseline: {:?}", gate.failed());
    assert!(!gate.checks.is_empty());

    // Dropping a gated variant is a failure, never a silent pass.
    let partial = EvalReport {
        models: vec![perfect_eval("eval@f32", "f32")],
        ..report.clone()
    };
    let gate = check_eval(&partial.to_json(), &baseline, None).unwrap();
    assert!(!gate.passed(), "missing gated variants must fail");
    assert!(gate.failed().iter().all(|c| c.current.is_none()));

    // Foreign and future baselines are refused typed.
    let dump = baseline.dump();
    let foreign =
        Json::parse(&dump.replace(EVAL_BASELINE_FORMAT, "mamba-x-bench-baseline")).unwrap();
    assert!(check_eval(&current, &foreign, None).is_err(), "foreign baseline refused");
    let future = Json::parse(&dump.replace("\"version\":1", "\"version\":99")).unwrap();
    let e = check_eval(&current, &future, None).unwrap_err();
    assert!(e.to_string().contains("newer"), "future baseline names the problem: {e}");

    // A future *report* is refused symmetrically.
    let cur_dump = current.dump();
    let future_report = Json::parse(&cur_dump.replace("\"version\":1", "\"version\":99")).unwrap();
    assert!(check_eval(&future_report, &baseline, None).is_err());
}
