//! Live model zoo properties (the acceptance gate for lazy artifact
//! loading + the dynamic hot-swap registry):
//!
//! * corruption matrix: a tensor corrupted *after* `open_lazy`'s eager
//!   phase (header + manifest + whole-file checksum) is caught typed
//!   (`ArtifactError::TensorCorrupt`, naming the tensor) on first touch —
//!   across f32 and INT8-quantized artifacts, at several blob positions —
//!   while an eager re-open of the same rotted file fails up front at the
//!   checksum gate; the lazy backend factory surfaces the same failure
//!   typed at build time, never as silent weight garbage;
//! * hot swap is bitwise invariant: requests served before a
//!   `swap_model` match the old weights' direct oracle bit-for-bit, and
//!   requests after match the new weights' oracle — batching and the
//!   swap window are invisible to response bits;
//! * books stay exact across add/swap/remove under concurrent load:
//!   every client-side admitted request is answered exactly once
//!   (completed, deadline_exceeded, or backend_failed — never lost), and
//!   engine-side ledgers reconcile with client-side tallies including
//!   the removed-model window (`rejected_unknown_model`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use mamba_x::config::VimModel;
use mamba_x::coordinator::{AdminError, BatchPolicy, EngineBuilder, RejectReason, Request};
use mamba_x::quant::TensorDtype;
use mamba_x::runtime::{
    native::synthetic_image, ArtifactError, ArtifactStore, InferenceBackend, ModelSource,
    ModelSpec, NativeBackend, Provenance, Tensor, TensorVerify, VerifyMode, VimArtifact,
};
use mamba_x::util::Pcg;
use mamba_x::vision::{ActMode, ForwardConfig, VimWeights};

/// Small-but-real model (same as `engine_props.rs` / `serving_props.rs`):
/// every datapath stage of the micro model, far fewer multiplies.
fn prop_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

fn temp_artifact_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "mamba_x_zoo_{tag}_{}_{:?}.mxa",
        std::process::id(),
        std::thread::current().id()
    ))
}

/// Byte offset where the tensor blob begins, read off the file image the
/// same way the store computes it (header 16 bytes, manifest, blob len).
fn blob_offset(bytes: &[u8]) -> usize {
    let mlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    16 + mlen + 8
}

/// Return a corrupted copy of the artifact image with tensor at
/// `span_off` rotted: for f32 records blow out the first element with
/// +inf (absmax goes NaN — a guaranteed integrity-record mismatch,
/// unlike a low-mantissa bit flip in a non-max element, which the
/// absmax record cannot see); for INT8 records blow out the first
/// dequantization scale the same way (a non-finite scale is refused
/// before any code dequantizes).
fn corrupt_tensor_at(pristine: &[u8], dtype: TensorDtype, span_off: usize, elems: usize) -> Vec<u8> {
    let mut bytes = pristine.to_vec();
    let blob = blob_offset(pristine);
    let target = match dtype {
        // First element of the f32 data.
        TensorDtype::F32 => blob + span_off,
        // First per-column scale (codes are `elems` bytes, scales follow).
        TensorDtype::I8 => blob + span_off + elems,
    };
    bytes[target..target + 4].copy_from_slice(&f32::INFINITY.to_le_bytes());
    bytes
}

/// ACCEPTANCE (corruption matrix): across f32 and quantized artifacts
/// and several tensor positions (first, seeded middle picks, last), a
/// tensor corrupted after the lazy eager phase fails typed on first
/// touch with the tensor's name, other tensors still verify, the
/// background verifier and `materialize` surface the same typed error,
/// and an eager `open` of the rotted file fails at the checksum gate.
#[test]
fn corruption_after_eager_phase_caught_typed_matrix() {
    let cfg = prop_cfg();
    for quantized in [false, true] {
        let mut weights = VimWeights::init(&cfg, 21);
        if quantized {
            let plan =
                mamba_x::quant::WeightQuantPlan::all_at_absmax(&weights.weight_quant_candidates());
            weights.apply_weight_quant(&plan).unwrap();
        }
        let art = VimArtifact::from_weights(
            weights,
            None,
            Provenance { tool: "zoo-props".into(), detail: "corruption matrix".into() },
        )
        .unwrap();
        let path = temp_artifact_path(if quantized { "matrix_i8" } else { "matrix_f32" });
        ArtifactStore::save(&path, &art).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        // Per-tensor spans, manifest order, recomputed like the store's.
        let probe = ArtifactStore::open_lazy(&path).unwrap();
        let tensors = probe.manifest().tensors.clone();
        let mut offsets = Vec::new();
        let mut off = 0usize;
        for t in &tensors {
            offsets.push(off);
            off += t.stored_bytes() as usize;
        }
        // Matrix of positions: ends plus seeded middle picks; under the
        // quantized artifact make sure at least one INT8 record is hit.
        let mut rng = Pcg::new(0x500 + quantized as u64);
        let mut picks = vec![0, tensors.len() - 1];
        for _ in 0..3 {
            picks.push(rng.usize_in(1, tensors.len() - 2));
        }
        if quantized {
            let i8_idx = tensors
                .iter()
                .position(|t| t.dtype == TensorDtype::I8)
                .expect("quantized artifact stores INT8 records");
            picks.push(i8_idx);
        }
        picks.sort_unstable();
        picks.dedup();

        for idx in picks {
            let meta = &tensors[idx];
            let elems: usize = meta.shape.iter().product();
            // Eager phase on the pristine image passes...
            std::fs::write(&path, &pristine).unwrap();
            let handle = ArtifactStore::open_lazy(&path).unwrap();
            // ...then the file rots underneath the handle.
            let rotted = corrupt_tensor_at(&pristine, meta.dtype, offsets[idx], elems);
            std::fs::write(&path, &rotted).unwrap();

            for (i, _) in tensors.iter().enumerate() {
                if i == idx {
                    let err = handle.verify_tensor(i).unwrap_err();
                    match &err {
                        ArtifactError::TensorCorrupt { name, .. } => assert_eq!(
                            name, &meta.name,
                            "typed error names the corrupted tensor ({:?})",
                            meta.dtype
                        ),
                        other => panic!("want TensorCorrupt for {:?}, got {other}", meta.name),
                    }
                    assert_eq!(handle.tensor_states()[i], TensorVerify::Failed);
                } else {
                    handle.verify_tensor(i).unwrap_or_else(|e| {
                        panic!("tensor {i} is clean but failed: {e} (corrupted {idx})")
                    });
                }
            }
            // materialize and the background verifier surface it typed.
            assert!(matches!(handle.materialize(), Err(ArtifactError::TensorCorrupt { .. })));
            assert!(matches!(
                handle.spawn_verifier().join().unwrap(),
                Err(ArtifactError::TensorCorrupt { .. })
            ));
            // Eager open of the rotted file never hands out weights: the
            // whole-file checksum gate fires before any tensor decodes.
            assert!(matches!(ArtifactStore::open(&path), Err(ArtifactError::Checksum { .. })));
        }
        std::fs::remove_file(&path).unwrap();
    }
}

/// The factory surface of the same guarantee: a lazy factory built over
/// a then-valid artifact fails typed at backend-build time once the file
/// rots (the memoized materialization error mentions the origin), and an
/// eager factory over the rotted file refuses at construction.
#[test]
fn lazy_factory_surfaces_corruption_typed_at_build() {
    let cfg = prop_cfg();
    let art = VimArtifact::from_weights(
        VimWeights::init(&cfg, 22),
        None,
        Provenance { tool: "zoo-props".into(), detail: "lazy factory".into() },
    )
    .unwrap();
    let path = temp_artifact_path("lazy_factory");
    ArtifactStore::save(&path, &art).unwrap();
    let pristine = std::fs::read(&path).unwrap();

    // Eager phase passes while the file is sound.
    let factory = NativeBackend::factory_ex(
        ModelSource::Artifact(path.clone()),
        None,
        None,
        VerifyMode::Lazy,
        ActMode::F32,
    )
    .expect("sound artifact passes the eager phase");

    let meta = {
        let probe = ArtifactStore::open_lazy(&path).unwrap();
        probe.manifest().tensors[1].clone()
    };
    let span_off = {
        let probe = ArtifactStore::open_lazy(&path).unwrap();
        probe.manifest().tensors[..1].iter().map(|t| t.stored_bytes() as usize).sum::<usize>()
    };
    let elems: usize = meta.shape.iter().product();
    std::fs::write(&path, corrupt_tensor_at(&pristine, meta.dtype, span_off, elems)).unwrap();

    // First build touches every tensor: typed failure, never garbage.
    let err = factory(0).expect_err("corrupted tensor fails the lazy build");
    let msg = format!("{err:#}");
    assert!(msg.contains("lazy materialization"), "memoized origin in error: {msg}");
    // The error is memoized — a second worker build fails identically
    // instead of re-reading the rotted file into a different state.
    let err2 = factory(1).expect_err("memoized failure repeats");
    assert!(format!("{err2:#}").contains("lazy materialization"), "{err2:#}");

    // Eager semantics preserved: the classic factory refuses up front.
    assert!(
        NativeBackend::factory_ex(
            ModelSource::Artifact(path.clone()),
            None,
            None,
            VerifyMode::Eager,
            ActMode::F32,
        )
        .is_err(),
        "verify=eager catches the rot at construction"
    );
    std::fs::remove_file(&path).unwrap();
}

fn spec_for_seed(name: &str, cfg: &ForwardConfig, seed: u64) -> ModelSpec {
    let source = ModelSource::RandomInit { config: cfg.clone(), seed };
    ModelSpec::new(name, NativeBackend::factory(source, None, None).unwrap())
}

/// ACCEPTANCE (hot-swap bitwise invariance): responses before a swap are
/// bit-identical to the old weights' direct oracle; responses admitted
/// after the swap are bit-identical to the new weights' oracle. The
/// report records the swap and the final epoch.
#[test]
fn hot_swap_is_bitwise_invariant() {
    let cfg = prop_cfg();
    let (seed_a, seed_b) = (31u64, 32u64);
    let n_elems = cfg.input_len();
    let (engine, join) = EngineBuilder::new()
        .workers(2)
        .policy(BatchPolicy { max_batch: 4, max_wait_us: 200 })
        .queue_depth(64)
        .register(spec_for_seed("zoo@m", &cfg, seed_a))
        .unwrap()
        .build()
        .unwrap();

    let mut before = Vec::new();
    for id in 0..6u64 {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(9, id, n_elems)).unwrap();
        before.push((id, engine.infer(Request::new("zoo@m", id, img)).unwrap().logits));
    }
    engine.swap_model("zoo@m", spec_for_seed("zoo@m", &cfg, seed_b)).unwrap();
    let mut after = Vec::new();
    for id in 10..16u64 {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(9, id, n_elems)).unwrap();
        after.push((id, engine.infer(Request::new("zoo@m", id, img)).unwrap().logits));
    }
    drop(engine);
    let report = join.join().unwrap();

    let mut oracle_a = NativeBackend::new(&cfg, seed_a);
    let mut oracle_b = NativeBackend::new(&cfg, seed_b);
    for (id, logits) in before {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(9, id, n_elems)).unwrap();
        assert_eq!(logits, oracle_a.infer(&img).unwrap(), "pre-swap req {id} runs old weights");
    }
    for (id, logits) in after {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(9, id, n_elems)).unwrap();
        assert_eq!(logits, oracle_b.infer(&img).unwrap(), "post-swap req {id} runs new weights");
    }
    let m = report.model("zoo@m").expect("swapped model reported");
    assert_eq!(m.swaps, 1, "one hot swap recorded");
    assert_eq!(m.epoch, 1, "weight epoch advanced once");
    assert!(!m.retired);
    assert_eq!(m.metrics.count(), 12, "all 12 requests completed");
}

/// ACCEPTANCE (chaos books): under concurrent client load, the zoo is
/// reshaped live — add a second variant, hot-swap the first twice
/// (exercising the pruned-epoch window), remove the second, re-add it —
/// and the ledgers stay exact: every client-admitted request is
/// answered exactly once, engine-side
/// `completed + deadline_exceeded + backend_failed` equals client-side
/// admissions, and unknown-model refusals (the not-yet-added and
/// removed windows) reconcile with the engine counter. Zero requests
/// lost.
#[test]
fn books_reconcile_across_add_swap_remove_under_load() {
    let cfg = prop_cfg();
    let (engine, join) = EngineBuilder::new()
        .workers(2)
        .policy(BatchPolicy { max_batch: 4, max_wait_us: 200 })
        .queue_depth(64)
        .register(spec_for_seed("zoo@a", &cfg, 41))
        .unwrap()
        .build()
        .unwrap();

    let admitted = Arc::new(AtomicU64::new(0));
    let completed = Arc::new(AtomicU64::new(0));
    let failed_after_admit = Arc::new(AtomicU64::new(0));
    let unknown = Arc::new(AtomicU64::new(0));
    let other_rejects = Arc::new(AtomicU64::new(0));

    let mut clients = Vec::new();
    for c in 0..2usize {
        let eng = engine.clone();
        let shape = cfg.input_shape();
        let (admitted, completed, failed, unknown, other) = (
            Arc::clone(&admitted),
            Arc::clone(&completed),
            Arc::clone(&failed_after_admit),
            Arc::clone(&unknown),
            Arc::clone(&other_rejects),
        );
        clients.push(std::thread::spawn(move || {
            for i in 0..60usize {
                let id = (c * 1000 + i) as u64;
                let model = if i % 2 == 0 { "zoo@a" } else { "zoo@b" };
                let img =
                    Tensor::new(shape.clone(), synthetic_image(5, id, shape.iter().product()))
                        .unwrap();
                match eng.submit(Request::new(model, id, img)) {
                    Ok(waiter) => {
                        admitted.fetch_add(1, Ordering::Relaxed);
                        match waiter.wait() {
                            Ok(resp) => {
                                assert_eq!(resp.id, id);
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            // Admitted but not served (e.g. its epoch was
                            // pruned by a double swap): typed, counted —
                            // never lost, never a hang.
                            Err(e) => {
                                assert!(
                                    e.reject_reason().is_none(),
                                    "post-admission failure must not be a rejection: {e}"
                                );
                                failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Err(e) if e.reject_reason() == Some(RejectReason::UnknownModel) => {
                        unknown.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        other.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if i % 16 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        }));
    }

    // Reshape the zoo while the clients hammer it.
    let nap = |ms: u64| std::thread::sleep(std::time::Duration::from_millis(ms));
    nap(5);
    engine.add_model(spec_for_seed("zoo@b", &cfg, 42)).unwrap();
    nap(5);
    engine.swap_model("zoo@a", spec_for_seed("zoo@a", &cfg, 43)).unwrap();
    engine.swap_model("zoo@a", spec_for_seed("zoo@a", &cfg, 44)).unwrap();
    nap(5);
    engine.remove_model("zoo@b").unwrap();
    nap(5);
    engine.add_model(spec_for_seed("zoo@b", &cfg, 45)).unwrap();

    for cl in clients {
        cl.join().unwrap();
    }
    drop(engine);
    let report = join.join().unwrap();

    let admitted = admitted.load(Ordering::Relaxed);
    let completed = completed.load(Ordering::Relaxed);
    let failed = failed_after_admit.load(Ordering::Relaxed);
    let unknown = unknown.load(Ordering::Relaxed);
    let other = other_rejects.load(Ordering::Relaxed);
    assert_eq!(
        admitted + unknown + other,
        120,
        "every client request lands in exactly one outcome class"
    );
    assert_eq!(admitted, completed + failed, "no admitted request is lost or double-answered");

    // Engine-side ledger matches the client-side one exactly.
    let merged = report.merged();
    assert_eq!(report.completed() as u64, completed, "completed reconciles");
    assert_eq!(
        merged.count() as u64 + merged.deadline_exceeded + merged.backend_failed,
        admitted,
        "engine books: admitted == completed + deadline_exceeded + backend_failed"
    );
    assert_eq!(merged.deadline_exceeded + merged.backend_failed, failed, "failures reconcile");
    assert_eq!(report.rejected_unknown_model, unknown, "removed/not-yet-added window counted");

    let a = report.model("zoo@a").expect("zoo@a reported");
    assert_eq!(a.swaps, 2, "both hot swaps recorded");
    assert_eq!(a.epoch, 2);
    let b = report.model("zoo@b").expect("zoo@b reported");
    assert!(!b.retired, "re-added after removal");
    assert!(b.epoch >= 1, "re-add re-activated the entry via a swap-in");
}

/// The removed window and re-add semantics, deterministically: removal
/// makes submissions fail typed `UnknownModel` (counted engine-side),
/// admin ops on the removed name fail typed `AdminError::UnknownModel`,
/// re-adding the name serves the *new* weights bit-exactly, and a
/// duplicate live add is refused.
#[test]
fn removed_window_typed_and_readd_serves_new_weights() {
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 2, max_wait_us: 100 })
        .queue_depth(16)
        .register(spec_for_seed("zoo@x", &cfg, 51))
        .unwrap()
        .build()
        .unwrap();
    let img = |id: u64| Tensor::new(cfg.input_shape(), synthetic_image(3, id, n_elems)).unwrap();

    let first = engine.infer(Request::new("zoo@x", 1, img(1))).unwrap();
    assert_eq!(first.logits, NativeBackend::new(&cfg, 51).infer(&img(1)).unwrap());

    engine.remove_model("zoo@x").unwrap();
    let err = engine.infer(Request::new("zoo@x", 2, img(2))).unwrap_err();
    assert_eq!(err.reject_reason(), Some(RejectReason::UnknownModel));
    assert!(matches!(engine.remove_model("zoo@x"), Err(AdminError::UnknownModel(_))));
    assert!(matches!(
        engine.swap_model("zoo@x", spec_for_seed("zoo@x", &cfg, 52)),
        Err(AdminError::UnknownModel(_))
    ));
    assert!(engine.models().is_empty(), "retired names leave the live list");

    engine.add_model(spec_for_seed("zoo@x", &cfg, 52)).unwrap();
    assert_eq!(engine.models(), vec!["zoo@x".to_string()]);
    assert!(matches!(
        engine.add_model(spec_for_seed("zoo@x", &cfg, 53)),
        Err(AdminError::DuplicateModel(_))
    ));
    let second = engine.infer(Request::new("zoo@x", 3, img(3))).unwrap();
    assert_eq!(
        second.logits,
        NativeBackend::new(&cfg, 52).infer(&img(3)).unwrap(),
        "re-added name serves the new generation's weights"
    );

    drop(engine);
    let report = join.join().unwrap();
    assert_eq!(report.rejected_unknown_model, 1);
    let m = report.model("zoo@x").expect("entry survives into the report");
    assert!(!m.retired);
    assert_eq!(m.metrics.count(), 2, "books accumulate across the generations");
}

/// REGRESSION (tombstone reap): removing a model under concurrent load
/// retires it immediately but releases its weights only after every
/// in-flight job for the name drains — `health()` flips `reaped` once
/// the queue window closes, the books stay exact across the drain, and
/// re-adding the name revives the entry (`reaped == false`, monotone
/// epoch) serving the new generation's weights bit-exactly.
#[test]
fn retired_tombstone_reaps_after_drain_and_readd_revives() {
    let cfg = prop_cfg();
    let n_elems = cfg.input_len();
    let (engine, join) = EngineBuilder::new()
        .workers(2)
        .policy(BatchPolicy { max_batch: 4, max_wait_us: 200 })
        .queue_depth(64)
        .register(spec_for_seed("zoo@keep", &cfg, 61))
        .unwrap()
        .register(spec_for_seed("zoo@gone", &cfg, 62))
        .unwrap()
        .build()
        .unwrap();

    // Hammer the doomed model until removal makes submissions fail
    // typed; count both windows exactly.
    let admitted = Arc::new(AtomicU64::new(0));
    let completed = Arc::new(AtomicU64::new(0));
    let unknown = Arc::new(AtomicU64::new(0));
    let client = {
        let eng = engine.clone();
        let shape = cfg.input_shape();
        let (admitted, completed, unknown) =
            (Arc::clone(&admitted), Arc::clone(&completed), Arc::clone(&unknown));
        std::thread::spawn(move || {
            for id in 0..80u64 {
                let img =
                    Tensor::new(shape.clone(), synthetic_image(6, id, shape.iter().product()))
                        .unwrap();
                match eng.submit(Request::new("zoo@gone", id, img)) {
                    Ok(waiter) => {
                        admitted.fetch_add(1, Ordering::Relaxed);
                        waiter.wait().expect("admitted pre-removal jobs drain normally");
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        assert_eq!(
                            e.reject_reason(),
                            Some(RejectReason::UnknownModel),
                            "post-removal submissions fail typed"
                        );
                        unknown.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(5));
    engine.remove_model("zoo@gone").unwrap();
    client.join().unwrap();

    // Keeper traffic cycles the workers so the loop-bottom reap check
    // runs after the last in-flight `zoo@gone` job settles; poll health
    // until the tombstone's weights are released.
    let mut reaped = false;
    for round in 0..200u64 {
        let img = Tensor::new(cfg.input_shape(), synthetic_image(7, round, n_elems)).unwrap();
        engine.infer(Request::new("zoo@keep", round, img)).unwrap();
        let health = engine.health();
        let gone = health.models.iter().find(|m| m.name == "zoo@gone").expect("tombstone listed");
        assert!(gone.retired, "removed name stays retired while tombstoned");
        let keep = health.models.iter().find(|m| m.name == "zoo@keep").unwrap();
        assert!(!keep.retired && !keep.reaped, "live sibling is never reaped");
        if gone.reaped {
            reaped = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(reaped, "drained tombstone releases its weights");

    // Re-adding the name revives the entry: weights are rebuilt at a
    // monotone epoch and the reaped flag clears.
    engine.add_model(spec_for_seed("zoo@gone", &cfg, 63)).unwrap();
    let health = engine.health();
    let gone = health.models.iter().find(|m| m.name == "zoo@gone").unwrap();
    assert!(!gone.retired && !gone.reaped, "re-add revives the reaped entry");
    assert!(gone.epoch >= 1, "revival swaps in at a fresh epoch");
    let img = Tensor::new(cfg.input_shape(), synthetic_image(8, 1, n_elems)).unwrap();
    let resp = engine.infer(Request::new("zoo@gone", 9001, img.clone())).unwrap();
    assert_eq!(
        resp.logits,
        NativeBackend::new(&cfg, 63).infer(&img).unwrap(),
        "revived name serves the new generation's weights bit-exactly"
    );

    drop(engine);
    let report = join.join().unwrap();
    let admitted = admitted.load(Ordering::Relaxed);
    let completed = completed.load(Ordering::Relaxed);
    let unknown = unknown.load(Ordering::Relaxed);
    assert_eq!(admitted + unknown, 80, "every client request lands in exactly one class");
    assert_eq!(admitted, completed, "no admitted request is lost across the reap");
    assert_eq!(report.rejected_unknown_model, unknown, "removed window reconciles");
    let gone = report.model("zoo@gone").expect("books survive the reap");
    assert_eq!(
        gone.metrics.count() as u64,
        completed + 1,
        "tombstone books are exact: drained jobs plus the revived probe"
    );
}
