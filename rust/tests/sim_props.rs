//! Property tests over the simulator and coordinator invariants
//! (hand-rolled harness: proptest is unavailable offline; `Pcg` provides
//! deterministic shrink-free random cases, 100+ per property).

use mamba_x::config::MambaXConfig;
use mamba_x::coordinator::{BatchPolicy, DynamicBatcher};
use mamba_x::quant::spe_scan_int_seq;
use mamba_x::sim::{scan_timing, ssa_scan_chunked_ref, ssa_scan_functional};
use mamba_x::sim::memory::Dram;
use mamba_x::util::Pcg;

/// PROPERTY: the SSA+LISU functional datapath equals the monolithic
/// sequential SPE scan for EVERY (chunk size, SSA count, shape) —
/// chunking is semantically invisible (the whole point of the LISU).
#[test]
fn prop_chunked_scan_schedule_invariant() {
    let mut rng = Pcg::new(0xC0FFEE);
    for case in 0..120 {
        let l = rng.usize_in(1, 90);
        let h = rng.usize_in(1, 5);
        let n = rng.usize_in(1, 5);
        let chunk = 1usize << rng.usize_in(1, 6);
        let n_ssa = rng.usize_in(1, 12);
        let total = l * h * n;
        let p: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
        let q: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
        let shift: Vec<i32> = (0..h).map(|_| rng.usize_in(0, 12) as i32).collect();
        let want = spe_scan_int_seq(&p, &q, &shift, l, h, n);
        let cfg = MambaXConfig { chunk, n_ssa, ..MambaXConfig::default() };
        let got = ssa_scan_functional(&cfg, &p, &q, &shift, l, h, n);
        assert_eq!(got, want, "case {case}: l={l} h={h} n={n} chunk={chunk} ssa={n_ssa}");
        let chunked = ssa_scan_chunked_ref(&cfg, &p, &q, &shift, l, h, n);
        assert_eq!(chunked, want, "case {case}: chunked ref diverged");
    }
}

/// PROPERTY: scan timing is monotone — more SSAs never slow it down, and
/// larger workloads never speed it up.
#[test]
fn prop_scan_timing_monotone() {
    let mut rng = Pcg::new(42);
    for _ in 0..60 {
        let l = rng.usize_in(64, 2048);
        let h = rng.usize_in(32, 512);
        let n = rng.usize_in(4, 16);
        let cycles = |n_ssa: usize, l: usize| {
            let cfg = MambaXConfig::with_ssas(n_ssa);
            let mut dram = Dram::new(cfg.dram_bytes_per_cycle());
            scan_timing(&cfg, &mut dram, l, h, n).cycles
        };
        assert!(cycles(2, l) >= cycles(4, l), "l={l} h={h} n={n}");
        assert!(cycles(4, l) >= cycles(8, l), "l={l} h={h} n={n}");
        assert!(cycles(8, 2 * l) > cycles(8, l), "l={l} h={h} n={n}");
    }
}

/// PROPERTY: DMA byte conservation — scan traffic equals exactly the
/// operand + output footprint, independent of schedule knobs.
#[test]
fn prop_scan_traffic_schedule_independent() {
    let mut rng = Pcg::new(7);
    for _ in 0..60 {
        let l = rng.usize_in(16, 1024);
        let h = rng.usize_in(16, 256);
        let n = rng.usize_in(2, 16);
        let expect_read = (3 * l * h + l * n + h * n) as f64;
        let expect_write = (l * h) as f64 * 2.0;
        for n_ssa in [1, 3, 8] {
            for chunk in [8, 16, 32] {
                let cfg = MambaXConfig { n_ssa, chunk, ..MambaXConfig::default() };
                let mut dram = Dram::new(cfg.dram_bytes_per_cycle());
                let t = scan_timing(&cfg, &mut dram, l, h, n);
                assert_eq!(t.dram_read_bytes, expect_read);
                assert_eq!(t.dram_write_bytes, expect_write);
                assert_eq!(dram.read_bytes, expect_read);
                assert_eq!(dram.write_bytes, expect_write);
            }
        }
    }
}

/// PROPERTY: the batcher is FIFO, lossless, duplicate-free, and never
/// exceeds max_batch — under arbitrary interleavings of push/poll.
#[test]
fn prop_batcher_fifo_lossless() {
    let mut rng = Pcg::new(99);
    for case in 0..100 {
        let max_batch = rng.usize_in(1, 10);
        let max_wait = rng.usize_in(0, 500) as u64;
        let mut b: DynamicBatcher<u64> =
            DynamicBatcher::new(BatchPolicy { max_batch, max_wait_us: max_wait });
        let n_items = rng.usize_in(1, 200);
        let mut sent = Vec::new();
        let mut recv = Vec::new();
        let mut now = 0u64;
        let mut next = 0u64;
        while recv.len() < n_items {
            now += rng.usize_in(0, 100) as u64;
            if next < n_items as u64 && rng.f64() < 0.6 {
                b.push(next, now);
                sent.push(next);
                next += 1;
            }
            if let Some(batch) = b.poll(now) {
                assert!(batch.len() <= max_batch, "case {case}");
                recv.extend(batch);
            }
            if next == n_items as u64 && !b.is_empty() {
                // Drain phase: keep polling with advancing time.
                now += max_wait + 1;
                if let Some(batch) = b.poll(now) {
                    assert!(batch.len() <= max_batch);
                    recv.extend(batch);
                }
            }
        }
        assert_eq!(recv, sent, "case {case}: FIFO order violated");
        assert_eq!(b.enqueued, b.dequeued);
    }
}

/// PROPERTY: a released batch is never stale — whenever poll returns at
/// time `now`, either the batch was full or the oldest item's deadline
/// had passed.
#[test]
fn prop_batcher_release_reason() {
    let mut rng = Pcg::new(123);
    for _ in 0..100 {
        let policy = BatchPolicy {
            max_batch: rng.usize_in(2, 8),
            max_wait_us: rng.usize_in(10, 1000) as u64,
        };
        let mut b: DynamicBatcher<(u64, u64)> = DynamicBatcher::new(policy);
        let mut now = 0u64;
        for i in 0..50u64 {
            now += rng.usize_in(0, 300) as u64;
            b.push((i, now), now);
            if let Some(batch) = b.poll(now) {
                let full = batch.len() == policy.max_batch;
                let oldest_enq = batch.first().unwrap().1;
                let expired = now >= oldest_enq + policy.max_wait_us;
                assert!(full || expired, "release without cause at t={now}");
            }
        }
    }
}

/// PROPERTY: GEMM-engine utilization is in (0, 1] and cycles scale
/// superlinearly never (doubling one dim at most ~doubles cycles + tiles).
#[test]
fn prop_gemm_sane() {
    use mamba_x::sim::gemm::gemm_timing;
    let mut rng = Pcg::new(5);
    for _ in 0..80 {
        let cfg = MambaXConfig::default();
        let m = rng.usize_in(1, 2048);
        let n = rng.usize_in(1, 2048);
        let k = rng.usize_in(1, 1024);
        let mut dram = Dram::new(cfg.dram_bytes_per_cycle());
        let t = gemm_timing(&cfg, &mut dram, m, n, k);
        assert!(t.utilization > 0.0 && t.utilization <= 1.0);
        assert!(t.cycles >= 1);
        let mut dram2 = Dram::new(cfg.dram_bytes_per_cycle());
        let t2 = gemm_timing(&cfg, &mut dram2, 2 * m, n, k);
        assert!(t2.cycles as f64 <= 2.6 * t.cycles as f64 + 1000.0);
    }
}
