//! Bit-exactness properties of the optimized hot paths (hand-rolled
//! harness: proptest is unavailable offline; `Pcg` provides deterministic
//! shrink-free random cases).
//!
//! Everything this PR made fast must be *bitwise* indistinguishable from
//! the seed's sequential reference implementations:
//!
//! * the register-tiled GEMM vs the naive triple loop;
//! * the lane-parallel (threaded) scan vs the per-lane sequential oracle,
//!   across every (L, H, N, chunk, n_ssa, threads) schedule;
//! * the batched forward pass vs per-item forward calls vs the pre-PR
//!   scalar reference forward.

use mamba_x::config::{MambaXConfig, VimModel};
use mamba_x::quant::{spe_scan_int, spe_scan_int_seq, spe_scan_int_threaded};
use mamba_x::sim::sfu::SfuTables;
use mamba_x::sim::{ssa_scan_chunked_ref, ssa_scan_functional};
use mamba_x::util::Pcg;
use mamba_x::vision::{matmul, matmul_ref, ForwardConfig, VimWeights};

/// PROPERTY: the tiled GEMM is bit-identical to the scalar reference for
/// arbitrary shapes (all tile-edge combinations) and bias modes.
#[test]
fn prop_tiled_gemm_matches_reference() {
    let mut rng = Pcg::new(0x6E44);
    for case in 0..150 {
        let m = rng.usize_in(1, 40);
        let k = rng.usize_in(1, 48);
        let n = rng.usize_in(1, 40);
        let x: Vec<f32> = (0..m * k).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let use_bias = rng.f64() < 0.5;
        let b = if use_bias { Some(bias.as_slice()) } else { None };
        assert_eq!(
            matmul(&x, &w, b, m, k, n),
            matmul_ref(&x, &w, b, m, k, n),
            "case {case}: {m}x{k}x{n} bias={use_bias}"
        );
    }
}

/// PROPERTY: the lane-parallel scan — auto-threaded, explicitly threaded
/// at any count, and through the SSA functional model at any (chunk,
/// n_ssa) — equals the sequential per-lane oracle bit-for-bit.
#[test]
fn prop_lane_parallel_scan_matches_sequential_oracle() {
    let mut rng = Pcg::new(0x5CA11);
    for case in 0..100 {
        let l = rng.usize_in(1, 70);
        let h = rng.usize_in(1, 9);
        let n = rng.usize_in(1, 7);
        let chunk = 1usize << rng.usize_in(1, 6);
        let n_ssa = rng.usize_in(1, 12);
        let threads = rng.usize_in(1, 9);
        let total = l * h * n;
        let p: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
        let q: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
        let shift: Vec<i32> = (0..h).map(|_| rng.usize_in(0, 12) as i32).collect();
        let want = spe_scan_int_seq(&p, &q, &shift, l, h, n);
        let ctx = format!("case {case}: l={l} h={h} n={n} chunk={chunk} ssa={n_ssa} t={threads}");
        assert_eq!(spe_scan_int(&p, &q, &shift, l, h, n), want, "auto {ctx}");
        assert_eq!(
            spe_scan_int_threaded(&p, &q, &shift, l, h, n, threads),
            want,
            "threaded {ctx}"
        );
        let cfg = MambaXConfig { chunk, n_ssa, ..MambaXConfig::default() };
        assert_eq!(ssa_scan_functional(&cfg, &p, &q, &shift, l, h, n), want, "functional {ctx}");
        assert_eq!(
            ssa_scan_chunked_ref(&cfg, &p, &q, &shift, l, h, n),
            want,
            "chunked ref {ctx}"
        );
    }
}

/// The auto-threading threshold only trips on large shapes; cover one
/// explicitly so the scoped-thread path runs under the test suite too.
#[test]
fn prop_large_scan_auto_threaded_matches_oracle() {
    let mut rng = Pcg::new(0xB16);
    let (l, h, n) = (300usize, 30usize, 16usize); // 144k elements > threshold
    let total = l * h * n;
    let p: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let q: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let shift: Vec<i32> = (0..h).map(|_| rng.usize_in(0, 12) as i32).collect();
    let want = spe_scan_int_seq(&p, &q, &shift, l, h, n);
    assert_eq!(spe_scan_int(&p, &q, &shift, l, h, n), want);
    for threads in [2usize, 5, 30, 64] {
        assert_eq!(spe_scan_int_threaded(&p, &q, &shift, l, h, n, threads), want, "t={threads}");
    }
}

/// Small-but-real model so the forward-pass cases stay fast in debug
/// builds (mirrors `rust/tests/serving_props.rs::prop_cfg`).
fn prop_cfg() -> ForwardConfig {
    ForwardConfig {
        model: VimModel {
            name: "prop",
            d_model: 16,
            n_blocks: 2,
            d_state: 4,
            expand: 2,
            conv_k: 4,
            patch: 4,
        },
        img: 8,
        in_ch: 1,
        n_classes: 6,
    }
}

/// PROPERTY: `forward_batch` is bitwise identical to per-item `forward`
/// calls — batch composition is invisible — and both equal the pre-PR
/// scalar reference `forward_ref`, across randomized weights, images,
/// batch sizes and scan schedules.
#[test]
fn prop_forward_batch_matches_per_item_and_reference() {
    let cfg = prop_cfg();
    let tables = SfuTables::fitted();
    let mut rng = Pcg::new(0xF0D);
    for case in 0..12u64 {
        let weights = VimWeights::init(&cfg, 50 + case);
        let scan = MambaXConfig {
            chunk: 1usize << rng.usize_in(2, 6),
            n_ssa: rng.usize_in(1, 8),
            ..MambaXConfig::default()
        };
        let b = rng.usize_in(1, 6);
        let imgs: Vec<Vec<f32>> = (0..b)
            .map(|i| {
                let mut r = Pcg::new(case * 100 + i as u64);
                (0..cfg.input_len()).map(|_| r.f32_in(-1.0, 1.0)).collect()
            })
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let batched = weights.forward_batch(&tables, &scan, &refs);
        assert_eq!(batched.len(), b, "case {case}");
        for (i, img) in imgs.iter().enumerate() {
            let item = weights.forward(&tables, &scan, img);
            let reference = weights.forward_ref(&tables, &scan, img);
            assert_eq!(item, reference, "case {case} img {i}: optimized != pre-PR reference");
            assert_eq!(batched[i], item, "case {case} img {i}: batch composition leaked");
        }
    }
}
