//! Chaos properties: seeded fault plans (panic / typed-error / latency
//! mixes) over random pool geometries. The invariants under injected
//! failure are the same ones the fair-weather tests assert:
//!
//! * surviving + respawned workers serve **bitwise-identical** results
//!   (fault injection and supervision must be invisible to a request
//!   that completes);
//! * exact counter reconciliation — every admitted request is answered
//!   exactly once (served, `DeadlineExceeded`, or `Backend`), and the
//!   engine report's books balance against what clients observed;
//! * the restart budget is respected: k faults < budget keeps the pool
//!   alive, sustained faults beyond it kill the pool with a typed
//!   error, never a hang.
//!
//! Hand-rolled Pcg harness, same idiom as `pool_props.rs`.

use std::sync::Arc;

use anyhow::Result;
use mamba_x::coordinator::{
    BatchPolicy, EngineBuilder, EngineError, Priority, RejectReason, Request,
};
use mamba_x::runtime::{FaultPlan, InferenceBackend, ModelFaults, ModelSpec, Tensor};
use mamba_x::util::Pcg;

/// Deterministic backend: logits are a pure function of the image, so
/// any two instances (original worker, respawned worker) must agree
/// bitwise.
struct Affine;

impl InferenceBackend for Affine {
    fn name(&self) -> &'static str {
        "affine"
    }

    fn infer(&mut self, image: &Tensor) -> Result<Vec<f32>> {
        Ok(vec![image.data.iter().sum::<f32>(), image.data[0] * 2.0 + 1.0])
    }
}

fn spec() -> ModelSpec {
    ModelSpec::new(
        "m",
        Arc::new(|_w| Ok(Box::new(Affine) as Box<dyn InferenceBackend>)),
    )
}

fn img(id: u64) -> Tensor {
    let v = id as f32;
    Tensor::new(vec![3], vec![v, v + 1.0, v + 2.0]).unwrap()
}

fn expected(id: u64) -> Vec<f32> {
    let v = id as f32;
    vec![v + (v + 1.0) + (v + 2.0), v * 2.0 + 1.0]
}

/// PROPERTY: with seeded panics at k ordinals < the restart budget,
/// over random pool geometries, the engine answers every admitted
/// request exactly once — completions bitwise-match direct inference,
/// failures are typed `Backend` errors — and the report reconciles.
#[test]
fn prop_seeded_panics_respawn_and_serve_bitwise_identical() {
    let mut rng = Pcg::new(0xC4A0);
    for case in 0..8 {
        let workers = rng.usize_in(1, 3);
        let max_batch = rng.usize_in(1, 3);
        let n = rng.usize_in(8, 24);
        // 1-2 panic ordinals per worker slot (fault ordinals are
        // per-slot and persist across respawns). Ordinal 1 is always
        // in the plan so every case provably injects at least once.
        let mut panic_on: Vec<u64> = vec![1];
        if rng.below(2) == 0 {
            panic_on.push(rng.usize_in(2, 6) as u64);
        }
        panic_on.sort_unstable();
        panic_on.dedup();
        let max_panics = (workers * panic_on.len()) as u32;
        let plan = FaultPlan {
            seed: case as u64,
            models: vec![ModelFaults {
                model: "m".into(),
                panic_on: panic_on.clone(),
                ..Default::default()
            }],
        };
        let (engine, join) = EngineBuilder::new()
            .workers(workers)
            .policy(BatchPolicy { max_batch, max_wait_us: 0 })
            .queue_depth(n)
            .restart_budget(16)
            .restart_backoff_ms(0)
            .breaker_threshold(0) // isolate supervision from the breaker
            .fault_plan(plan)
            .register(spec())
            .unwrap()
            .build()
            .unwrap();
        assert!(max_panics < 16, "case {case}: plan must stay under the budget");
        let (mut completed, mut failed) = (0u64, 0u64);
        for id in 0..n as u64 {
            match engine.infer(Request::new("m", id, img(id))) {
                Ok(resp) => {
                    assert_eq!(resp.id, id, "case {case}");
                    assert_eq!(
                        resp.logits,
                        expected(id),
                        "case {case}: respawned worker diverged bitwise"
                    );
                    completed += 1;
                }
                Err(EngineError::Backend(msg)) => {
                    assert!(msg.contains("panicked"), "case {case}: {msg}");
                    failed += 1;
                }
                Err(e) => panic!("case {case}: request {id} got unexpected failure {e}"),
            }
        }
        assert!(failed >= 1, "case {case}: ordinal 1 must fire on the first-served slot");
        let health = engine.health();
        assert_eq!(health.workers_total, workers, "case {case}");
        assert!(health.restarts <= u64::from(max_panics), "case {case}");
        drop(engine);
        let report = join
            .join()
            .unwrap_or_else(|e| panic!("case {case}: pool died despite budget headroom: {e}"));
        assert_eq!(report.workers, workers, "case {case}");
        // A respawn reserved by the final panic may complete between the
        // health snapshot and join, so the report may run ahead — never
        // behind, and never past what the plan could trigger.
        assert!(report.restarts >= health.restarts, "case {case}");
        assert!(report.restarts <= u64::from(max_panics), "case {case}");
        let m = &report.model("m").expect("registered model reported").metrics;
        assert_eq!(m.count() as u64, completed, "case {case}");
        assert_eq!(m.backend_failed, failed, "case {case}");
        assert_eq!(m.deadline_exceeded, 0, "case {case}");
        assert_eq!(
            completed + failed,
            n as u64,
            "case {case}: every admitted request answered exactly once"
        );
    }
}

/// PROPERTY: typed `Err` injection never kills a worker — zero
/// restarts — and every injected failure surfaces as a typed `Backend`
/// error carrying the injection marker, with exact books.
#[test]
fn prop_injected_errors_are_typed_and_conserved() {
    let mut rng = Pcg::new(0xE220);
    for case in 0..6 {
        let workers = rng.usize_in(1, 3);
        let n = rng.usize_in(8, 20);
        // Ordinal 1 is always present so every case injects at least
        // one error regardless of how calls spread across slots.
        let mut error_on: Vec<u64> = vec![1];
        for _ in 0..rng.usize_in(0, 2) {
            error_on.push(rng.usize_in(2, 5) as u64);
        }
        error_on.sort_unstable();
        error_on.dedup();
        let plan = FaultPlan {
            seed: 100 + case as u64,
            models: vec![ModelFaults {
                model: "m".into(),
                error_on: error_on.clone(),
                ..Default::default()
            }],
        };
        let (engine, join) = EngineBuilder::new()
            .workers(workers)
            .policy(BatchPolicy { max_batch: rng.usize_in(1, 3), max_wait_us: 0 })
            .queue_depth(n)
            .restart_budget(0) // a typed Err must never need a respawn
            .breaker_threshold(0)
            .fault_plan(plan)
            .register(spec())
            .unwrap()
            .build()
            .unwrap();
        let (mut completed, mut failed) = (0u64, 0u64);
        for id in 0..n as u64 {
            match engine.infer(Request::new("m", id, img(id))) {
                Ok(resp) => {
                    assert_eq!(resp.logits, expected(id), "case {case}");
                    completed += 1;
                }
                Err(EngineError::Backend(msg)) => {
                    assert!(msg.contains("injected fault"), "case {case}: {msg}");
                    failed += 1;
                }
                Err(e) => panic!("case {case}: unexpected failure {e}"),
            }
        }
        assert!(failed > 0, "case {case}: at least slot 0's first error ordinal fires");
        let health = engine.health();
        assert_eq!(health.restarts, 0, "case {case}: typed errors must not kill workers");
        assert_eq!(health.workers_alive, workers, "case {case}");
        assert!(!health.degraded(), "case {case}");
        drop(engine);
        let report = join.join().unwrap();
        assert_eq!(report.restarts, 0, "case {case}");
        let m = &report.model("m").expect("registered model reported").metrics;
        assert_eq!(m.count() as u64, completed, "case {case}");
        assert_eq!(m.backend_failed, failed, "case {case}");
        assert_eq!(completed + failed, n as u64, "case {case}: conservation");
    }
}

/// Breaker under chaos: consecutive injected failures trip the
/// per-model breaker into typed fast-fail; a half-open probe after the
/// cooldown closes it again once the fault plan runs dry.
#[test]
fn breaker_fast_fails_then_half_open_probe_recovers() {
    // Slot 0 fails its first call only; threshold 1 opens the breaker
    // on that failure, cooldown 0 admits the next request as a
    // half-open probe, which succeeds and closes the breaker.
    let plan = FaultPlan {
        seed: 5,
        models: vec![ModelFaults {
            model: "m".into(),
            error_on: vec![1],
            ..Default::default()
        }],
    };
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
        .breaker_threshold(1)
        .breaker_cooldown_ms(0)
        .fault_plan(plan)
        .register(spec())
        .unwrap()
        .build()
        .unwrap();
    let err = engine.infer(Request::new("m", 1, img(1))).unwrap_err();
    assert!(matches!(err, EngineError::Backend(_)), "{err}");
    assert_eq!(engine.health().models[0].breaker, "open");
    assert!(engine.health().degraded(), "open breaker must degrade health");
    // Cooldown 0: admitted as the half-open probe, fault plan is dry,
    // so it succeeds and the breaker closes.
    let resp = engine.infer(Request::new("m", 2, img(2))).unwrap();
    assert_eq!(resp.logits, expected(2));
    assert_eq!(engine.health().models[0].breaker, "closed");
    assert!(!engine.health().degraded());
    let resp = engine.infer(Request::new("m", 3, img(3))).unwrap();
    assert_eq!(resp.logits, expected(3));
    drop(engine);
    let report = join.join().unwrap();
    let m = &report.model("m").expect("registered model reported").metrics;
    assert_eq!(m.count(), 2);
    assert_eq!(m.backend_failed, 1);
    assert_eq!(m.rejected_breaker, 0, "no request arrived while open");
}

/// Breaker fast-fail is typed and counted: with a long cooldown, a
/// request arriving after the breaker opened is refused with
/// `RejectReason::BreakerOpen` without consuming a batch slot.
#[test]
fn open_breaker_rejects_typed_without_burning_slots() {
    let plan = FaultPlan {
        seed: 9,
        models: vec![ModelFaults {
            model: "m".into(),
            error_on: vec![1, 2],
            ..Default::default()
        }],
    };
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
        .breaker_threshold(2)
        .breaker_cooldown_ms(600_000) // no probe within this test
        .fault_plan(plan)
        .register(spec())
        .unwrap()
        .build()
        .unwrap();
    for id in 1..=2u64 {
        let err = engine.infer(Request::new("m", id, img(id))).unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)), "call {id}: {err}");
    }
    assert_eq!(engine.health().models[0].breaker, "open");
    match engine.infer(Request::new("m", 3, img(3))) {
        Err(EngineError::Rejected { reason: RejectReason::BreakerOpen, detail, .. }) => {
            assert!(detail.contains("circuit breaker"), "{detail}");
        }
        other => panic!("expected BreakerOpen fast-fail, got {other:?}"),
    }
    drop(engine);
    let report = join.join().unwrap();
    let m = &report.model("m").expect("registered model reported").metrics;
    assert_eq!(m.backend_failed, 2);
    assert_eq!(m.rejected_breaker, 1);
    assert_eq!(m.count(), 0);
}

/// PROPERTY: latency-spike injection plus per-request deadlines — every
/// admitted request resolves exactly once as Ok or a typed
/// `DeadlineExceeded` (deadlines are enforced at dequeue), and the
/// books balance including submit-time sheds.
#[test]
fn prop_latency_spikes_with_deadlines_keep_exact_books() {
    let mut rng = Pcg::new(0x51CE);
    for case in 0..5 {
        let n = rng.usize_in(6, 14);
        let plan = FaultPlan {
            seed: 200 + case as u64,
            models: vec![ModelFaults {
                model: "m".into(),
                spike_us: 15_000,
                spike_rate: 1.0,
                ..Default::default()
            }],
        };
        let (engine, join) = EngineBuilder::new()
            .workers(1)
            .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
            .queue_depth(n)
            .breaker_threshold(0)
            .fault_plan(plan)
            .register(spec())
            .unwrap()
            .build()
            .unwrap();
        // Submit everything up front (High priority: only Full or a
        // deadline-aware shed can refuse, and the queue is deep
        // enough): requests with microsecond deadlines expire in queue
        // behind the 15 ms spikes.
        let mut waiters = Vec::new();
        let mut shed = 0u64;
        for id in 0..n as u64 {
            let mut request = Request::new("m", id, img(id)).priority(Priority::High);
            if id % 2 == 1 {
                request = request.deadline_us(rng.usize_in(1, 400) as u64);
            }
            match engine.submit(request) {
                Ok(w) => waiters.push((id, w)),
                Err(EngineError::Rejected { reason: RejectReason::Shed, .. }) => shed += 1,
                Err(e) => panic!("case {case}: unexpected refusal {e}"),
            }
        }
        let accepted = waiters.len() as u64;
        let (mut completed, mut deadline_failed) = (0u64, 0u64);
        for (id, w) in waiters {
            match w.wait() {
                Ok(resp) => {
                    assert_eq!(resp.logits, expected(id), "case {case}");
                    completed += 1;
                }
                Err(EngineError::DeadlineExceeded { model, deadline_us, waited_us }) => {
                    assert_eq!(model, "m", "case {case}");
                    assert!(waited_us > deadline_us, "case {case}");
                    deadline_failed += 1;
                }
                Err(e) => panic!("case {case}: accepted request {id} got {e}"),
            }
        }
        assert_eq!(
            completed + deadline_failed,
            accepted,
            "case {case}: every accepted request answered"
        );
        assert!(deadline_failed + shed > 0, "case {case}: spikes must bite some deadline");
        drop(engine);
        let report = join.join().unwrap();
        let m = &report.model("m").expect("registered model reported").metrics;
        assert_eq!(m.count() as u64, completed, "case {case}");
        assert_eq!(m.deadline_exceeded, deadline_failed, "case {case}");
        assert_eq!(m.backend_failed, 0, "case {case}");
        assert_eq!(m.rejected_shed, shed, "case {case}");
        assert_eq!(accepted + shed, n as u64, "case {case}: conservation");
    }
}

/// Sustained panics past the restart budget kill the pool with typed
/// errors — exactly `budget` respawns, then `ShuttingDown` at submit
/// and an error at join. Never a hang, never a lost request.
#[test]
fn restart_budget_exhaustion_dies_typed_not_hanging() {
    let plan = FaultPlan {
        seed: 17,
        models: vec![ModelFaults {
            model: "m".into(),
            panic_on: vec![1, 2, 3],
            ..Default::default()
        }],
    };
    let (engine, join) = EngineBuilder::new()
        .workers(1)
        .policy(BatchPolicy { max_batch: 1, max_wait_us: 0 })
        .restart_budget(2)
        .restart_backoff_ms(0)
        .breaker_threshold(0)
        .fault_plan(plan)
        .register(spec())
        .unwrap()
        .build()
        .unwrap();
    // Three panic ordinals, budget 2: calls 1-3 each die with a typed
    // Backend error; the third exhausts the budget and the pool dies.
    let mut backend_errs = 0u64;
    let mut saw_shutdown = false;
    for id in 0..400u64 {
        match engine.infer(Request::new("m", id, img(id))) {
            Ok(resp) => assert_eq!(resp.logits, expected(id), "{id}"),
            Err(EngineError::Backend(_)) => backend_errs += 1,
            Err(EngineError::ShuttingDown) => {
                saw_shutdown = true;
                break;
            }
            Err(e) => panic!("request {id}: unexpected failure {e}"),
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(saw_shutdown, "pool must die after the budget is exhausted");
    // Three panic ordinals fail three requests; one more submit can be
    // admitted in the window before pool teardown completes, in which
    // case it is flushed with a typed Backend error (never lost).
    assert!(
        (3..=4).contains(&backend_errs),
        "each panic fails exactly one request (plus at most one flushed): {backend_errs}"
    );
    let health = engine.health();
    assert_eq!(health.restarts, 2, "exactly the budget");
    assert_eq!(health.workers_alive, 0);
    assert!(health.degraded());
    drop(engine);
    assert!(join.join().is_err(), "pool death must surface at join");
}
