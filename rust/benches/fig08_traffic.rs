//! Paper Fig 8: off-chip traffic of the selective SSM on A100 vs Jetson
//! AGX Xavier vs an ideal (infinite-SRAM) GPU, normalized to Ideal@224
//! READ. Expected shape: A100 tracks Ideal at every size; Xavier diverges
//! sharply at high resolution (shared-memory spills).

use mamba_x::config::{GpuConfig, VimModel, IMAGE_SIZES};
use mamba_x::gpu::GpuModel;
use mamba_x::vision::vim_selective_ssm_ops;

fn main() {
    println!("=== Fig 8: selective-SSM off-chip traffic (normalized) ===");
    let m = VimModel::tiny();
    let ideal = GpuModel::new(GpuConfig::ideal());
    let norm = ideal.run(&vim_selective_ssm_ops(&m, m.seq_len(224))).read_bytes;

    println!("{:>7} {:>6} {:>9} {:>9} {:>12}", "device", "img", "READ", "WRITE", "vs ideal");
    for dev in [GpuConfig::ideal(), GpuConfig::a100(), GpuConfig::xavier()] {
        let gm = GpuModel::new(dev.clone());
        for img in IMAGE_SIZES {
            let ops = vim_selective_ssm_ops(&m, m.seq_len(img));
            let r = gm.run(&ops);
            let id = ideal.run(&ops);
            let ratio = r.total_bytes() / id.total_bytes();
            println!(
                "{:>7} {:>6} {:>9.2} {:>9.2} {:>11.2}x",
                dev.name,
                img,
                r.read_bytes / norm,
                r.write_bytes / norm,
                ratio
            );
        }
    }

    // Assertions on the paper's qualitative result.
    let xavier = GpuModel::new(GpuConfig::xavier());
    let a100 = GpuModel::new(GpuConfig::a100());
    let big = vim_selective_ssm_ops(&m, m.seq_len(1024));
    let r_x = xavier.run(&big).total_bytes();
    let r_a = a100.run(&big).total_bytes();
    let r_i = ideal.run(&big).total_bytes();
    assert!(r_a / r_i < 1.05, "A100 ~ ideal (paper Fig 8)");
    assert!(r_x / r_i > 1.5, "Xavier >> ideal at 1024 (paper Fig 8)");
    println!(
        "\nXavier/ideal @1024: {:.2}x ; A100/ideal @1024: {:.2}x",
        r_x / r_i,
        r_a / r_i
    );
}
