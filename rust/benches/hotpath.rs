//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md), with a
//! machine-readable record: every run rewrites `BENCH_hotpath.json` with
//! (name, shape, mean_ns, throughput) per bench *plus* in-run
//! baseline-vs-optimized speedup pairs, so each commit's perf trajectory
//! is recorded (CI uploads the file as an artifact) and future PRs have a
//! floor to beat. Baselines are the seed's pre-optimization kernels
//! (`matmul_ref`, `spe_scan_int_seq`, `ssa_scan_chunked_ref`,
//! `forward_ref`), which stay in-tree as bit-exactness oracles.
//!
//! Set `HOTPATH_SMOKE=1` for a short CI smoke run (few iterations,
//! speedup asserts relaxed): `HOTPATH_SMOKE=1 cargo bench --bench hotpath`.

use mamba_x::config::{GpuConfig, MambaXConfig, VimModel};
use mamba_x::coordinator::{BatchPolicy, DynamicBatcher};
use mamba_x::gpu::GpuModel;
use mamba_x::quant::{
    spe_scan_int, spe_scan_int_batch_fused, spe_scan_int_seq, spe_scan_int_threaded,
};
use mamba_x::runtime::native::synthetic_image;
use mamba_x::sim::memory::Dram;
use mamba_x::sim::sfu::SfuTables;
use mamba_x::sim::{scan_timing, ssa_scan_chunked_ref, Accelerator};
use mamba_x::util::bench::{bench, report, BenchReport};
use mamba_x::util::Pcg;
use mamba_x::vision::{
    matmul, matmul_i8, matmul_ref, vim_model_ops, vim_selective_ssm_ops, ForwardConfig, ScanExec,
    VimWeights,
};

/// Checked-in fallback for the SFU tables so the bench never skips.
const SFU_FIXTURE: &str = "rust/tests/data/sfu_luts.json";

fn main() {
    let smoke = std::env::var("HOTPATH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    // (warmup, iters) for cheap and expensive benches.
    let (warm, iters) = if smoke { (1u32, 3u32) } else { (2, 20) };
    let (warm_big, iters_big) = if smoke { (0u32, 2u32) } else { (1, 8) };
    println!("=== hot-path microbenches{} ===", if smoke { " (smoke)" } else { "" });
    let mut rep = BenchReport::new("hotpath");

    // 1. Cycle scheduler at the largest paper shape (base@1024).
    let m = VimModel::base();
    let (l, h, n) = (m.seq_len(1024), m.d_inner(), m.d_state);
    let cfg = MambaXConfig::default();
    let jobs = (h * n * l.div_ceil(cfg.chunk)) as f64;
    let s = bench(warm_big, iters_big, || {
        let mut dram = Dram::new(cfg.dram_bytes_per_cycle());
        scan_timing(&cfg, &mut dram, l, h, n).cycles
    });
    rep.push("scan_timing(base@1024)", &format!("{l}x{h}x{n}"), jobs, s);

    // 2. Integer SPE datapath: sequential oracle (the pre-PR baseline,
    //    recorded every run) vs the lane-parallel hot path.
    let (sl, sh, sn) = (512usize, 64, 16);
    let shape = format!("{sl}x{sh}x{sn}");
    let total = sl * sh * sn;
    let mut rng = Pcg::new(1);
    let p: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let q: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let shift: Vec<i32> = (0..sh).map(|_| 7).collect();
    let s = bench(warm, iters, || spe_scan_int_seq(&p, &q, &shift, sl, sh, sn));
    rep.push("spe_scan_int_seq(512x64x16)", &shape, total as f64, s);
    let s = bench(warm, iters, || spe_scan_int_threaded(&p, &q, &shift, sl, sh, sn, 1));
    rep.push("spe_scan_int_1t(512x64x16)", &shape, total as f64, s);
    let s = bench(warm, iters, || spe_scan_int(&p, &q, &shift, sl, sh, sn));
    rep.push("spe_scan_int(512x64x16)", &shape, total as f64, s);
    let scan_cfg = MambaXConfig::default();
    let s = bench(warm, iters, || ssa_scan_chunked_ref(&scan_cfg, &p, &q, &shift, sl, sh, sn));
    rep.push("ssa_scan_chunked_ref(512x64x16)", &shape, total as f64, s);
    let scan_speedup = rep.speedup(
        "spe_scan_int_vs_seq",
        "spe_scan_int_seq(512x64x16)",
        "spe_scan_int(512x64x16)",
    );
    rep.speedup(
        "spe_scan_int_vs_chunked_lane_major",
        "ssa_scan_chunked_ref(512x64x16)",
        "spe_scan_int(512x64x16)",
    );

    // 2b. Batch fusion at the micro serve shape: 8 items of (65, 128, 8).
    //     One item sits below the threading threshold, so per-item scans
    //     (the dynamic-scale seam) run single-threaded; the fused walk —
    //     what a static calibration table enables — sees all B·H·N lanes
    //     at once.
    let (bl, bh, bn, bb) = (65usize, 128usize, 8usize, 8usize);
    let per = bl * bh * bn;
    let bshape = format!("{bb}x{bl}x{bh}x{bn}");
    let pb: Vec<i64> = (0..bb * per).map(|_| rng.int8()).collect();
    let qb: Vec<i64> = (0..bb * per).map(|_| rng.int8()).collect();
    let bshift: Vec<i32> = (0..bh).map(|i| (i % 11) as i32).collect();
    let s = bench(warm, iters, || {
        (0..bb)
            .map(|it| {
                let span = it * per..(it + 1) * per;
                spe_scan_int(&pb[span.clone()], &qb[span], &bshift, bl, bh, bn)
            })
            .collect::<Vec<_>>()
    });
    rep.push("spe_scan_per_item_x8(65x128x8)", &bshape, (bb * per) as f64, s);
    let s = bench(warm, iters, || spe_scan_int_batch_fused(&pb, &qb, &bshift, bb, bl, bh, bn));
    rep.push("spe_scan_batch_fused_x8(65x128x8)", &bshape, (bb * per) as f64, s);
    rep.speedup(
        "scan_batch_fused_vs_per_item",
        "spe_scan_per_item_x8(65x128x8)",
        "spe_scan_batch_fused_x8(65x128x8)",
    );

    // 3. Register-tiled GEMM vs the naive triple loop, at the batch-8
    //    in-projection shape of the micro serving model.
    let (gm, gk, gn) = (8 * 65usize, 64usize, 256usize);
    let gshape = format!("{gm}x{gk}x{gn}");
    let macs = (gm * gk * gn) as f64;
    let x: Vec<f32> = (0..gm * gk).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let w: Vec<f32> = (0..gk * gn).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let bias: Vec<f32> = (0..gn).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let s = bench(warm, iters, || matmul_ref(&x, &w, Some(&bias), gm, gk, gn));
    rep.push("matmul_ref(520x64x256)", &gshape, macs, s);
    let s = bench(warm, iters, || matmul(&x, &w, Some(&bias), gm, gk, gn));
    rep.push("matmul(520x64x256)", &gshape, macs, s);
    rep.speedup("matmul_vs_ref", "matmul_ref(520x64x256)", "matmul(520x64x256)");

    // 3b. INT8xINT8 GEMM vs the f32 tiled kernel at a weight-heavy shape
    //     (the quantized-artifact hot path): same MAC count, i32
    //     register-tile accumulation, 4x less weight traffic per operand.
    //     The `gemm_i8_vs_f32` floor in BENCH_baseline.json keeps the
    //     INT8 kernel from quietly losing to the f32 path it replaces.
    let (qm, qk, qn) = (32usize, 512usize, 2048usize);
    let qshape = format!("{qm}x{qk}x{qn}");
    let qmacs = (qm * qk * qn) as f64;
    let qx: Vec<i8> = (0..qm * qk).map(|_| rng.int8() as i8).collect();
    let qw: Vec<i8> = (0..qk * qn).map(|_| rng.int8() as i8).collect();
    let xsc: Vec<f32> = (0..qm).map(|_| rng.f32_in(0.005, 0.02)).collect();
    let wsc: Vec<f32> = (0..qn).map(|_| rng.f32_in(0.005, 0.02)).collect();
    let qbias: Vec<f32> = (0..qn).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    // The f32 contender multiplies the dequantized operands — what
    // serving would run without weight quantization.
    let fx: Vec<f32> = qx.iter().enumerate().map(|(i, &v)| v as f32 * xsc[i / qk]).collect();
    let fw: Vec<f32> = qw.iter().enumerate().map(|(i, &v)| v as f32 * wsc[i % qn]).collect();
    let s = bench(warm_big, iters_big, || matmul(&fx, &fw, Some(&qbias), qm, qk, qn));
    rep.push("matmul_f32(32x512x2048)", &qshape, qmacs, s);
    let s = bench(warm_big, iters_big, || {
        matmul_i8(&qx, &xsc, &qw, &wsc, Some(&qbias), qm, qk, qn)
    });
    rep.push("matmul_i8(32x512x2048)", &qshape, qmacs, s);
    rep.speedup("gemm_i8_vs_f32", "matmul_f32(32x512x2048)", "matmul_i8(32x512x2048)");

    // 4. SFU LUT evaluation: prefer fitted artifacts, fall back to the
    //    checked-in golden fixture so this bench always runs.
    let tables = SfuTables::load("artifacts/sfu_luts.json")
        .or_else(|_| SfuTables::load(SFU_FIXTURE))
        .unwrap_or_else(|e| {
            println!("(sfu fixture unavailable: {e}; using fitted tables)");
            SfuTables::fitted()
        });
    let xs: Vec<f32> = (0..65536).map(|i| -8.0 + 16.0 * (i as f32 / 65536.0)).collect();
    let s = bench(warm, iters, || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += tables.silu.eval(x);
        }
        acc
    });
    rep.push("sfu.silu_lut(64k evals)", "65536", 65536.0, s);

    // 5. Batcher throughput: fresh-Vec poll (pre-PR) vs buffer-reusing
    //    poll_into, with a micro-assert that reuse did not regress.
    let run_batcher = |reuse: bool| {
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 8, max_wait_us: 100 });
        let mut out = 0usize;
        let mut buf: Vec<u64> = Vec::new();
        for i in 0..10_000u64 {
            b.push(i, i);
            if reuse {
                if b.poll_into(i, &mut buf) {
                    out += buf.len();
                }
            } else if let Some(batch) = b.poll(i) {
                out += batch.len();
            }
        }
        out + b.flush().len()
    };
    let s = bench(warm, iters, || run_batcher(false));
    rep.push("batcher_alloc(10k reqs)", "10000", 10_000.0, s);
    let s = bench(warm, iters, || run_batcher(true));
    rep.push("batcher_reuse(10k reqs)", "10000", 10_000.0, s);
    let batcher_speedup = rep
        .speedup("batcher_reuse_vs_alloc", "batcher_alloc(10k reqs)", "batcher_reuse(10k reqs)")
        .expect("both batcher records present");
    if !smoke {
        // Micro-assert: buffer reuse must not cost throughput (generous
        // slack — this guards regressions, not noise).
        assert!(
            batcher_speedup > 0.8,
            "batcher poll_into regressed vs poll: {batcher_speedup:.2}x"
        );
    }

    // 6. Native quantized Vim forward, micro serving model, batch of 8:
    //    pre-PR per-item reference path vs the optimized per-item path vs
    //    the one-GEMM-pass batched path the pool workers now call.
    let fcfg = ForwardConfig::micro();
    let weights = VimWeights::init(&fcfg, 7);
    let sfu = SfuTables::fitted();
    let scan = MambaXConfig::default();
    let imgs: Vec<Vec<f32>> =
        (0..8).map(|id| synthetic_image(3, id, fcfg.input_len())).collect();
    let img_refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let s = bench(warm_big, iters_big, || {
        imgs.iter().map(|img| weights.forward_ref(&sfu, &scan, img)).collect::<Vec<_>>()
    });
    rep.push("native_forward_ref_x8(micro)", "batch=8", 8.0, s);
    let s = bench(warm_big, iters_big, || {
        imgs.iter().map(|img| weights.forward(&sfu, &scan, img)).collect::<Vec<_>>()
    });
    rep.push("native_forward_x8(micro)", "batch=8", 8.0, s);
    let s = bench(warm_big, iters_big, || weights.forward_batch(&sfu, &scan, &img_refs));
    rep.push("native_forward_batch8(micro)", "batch=8", 8.0, s);
    let fwd_speedup = rep.speedup(
        "forward_batch8_vs_prepr_per_item",
        "native_forward_ref_x8(micro)",
        "native_forward_batch8(micro)",
    );
    rep.speedup(
        "forward_batch8_vs_per_item",
        "native_forward_x8(micro)",
        "native_forward_batch8(micro)",
    );

    // 6b. Static calibration: table built (max-abs) from the same 8
    //     images, then the batched forward with the batch-fused quantized
    //     scan vs the dynamic per-item-scan batched path above.
    let calib = weights.calibrate(&sfu, &scan, &img_refs, 1.0).expect("calibration pass");
    let s = bench(warm_big, iters_big, || {
        let mut exec = ScanExec::Static(&calib);
        weights.forward_batch_ex(&sfu, &scan, &img_refs, &mut exec)
    });
    rep.push("native_forward_batch8_calib(micro)", "batch=8", 8.0, s);
    let calib_speedup = rep.speedup(
        "forward_batch8_calib_vs_dynamic",
        "native_forward_batch8(micro)",
        "native_forward_batch8_calib(micro)",
    );

    // 6c. Model-zoo cold start: eager `ArtifactStore::open` (full tensor
    //     decode + per-tensor integrity checks) vs `open_lazy` (header +
    //     manifest + streamed whole-file checksum; tensor verification
    //     deferred to first touch) on a saved micro_l artifact — the
    //     serving engine's `"verify": "lazy"` path. The `zoo_cold_start`
    //     floor in BENCH_baseline.json keeps lazy open meaningfully
    //     cheaper than the eager open it defers.
    let zoo_speedup = {
        use mamba_x::runtime::{ArtifactStore, Provenance, VimArtifact};
        let zcfg = ForwardConfig::micro_l();
        let art = VimArtifact::from_weights(
            VimWeights::init(&zcfg, 11),
            None,
            Provenance { tool: "hotpath-bench".into(), detail: "zoo cold-start fixture".into() },
        )
        .expect("micro_l packages as an artifact");
        let path = std::env::temp_dir()
            .join(format!("mamba_x_zoo_cold_start_{}.mxa", std::process::id()));
        ArtifactStore::save(&path, &art).expect("save cold-start bench artifact");
        let s = bench(warm_big, iters_big, || {
            ArtifactStore::open(&path).expect("eager open").manifest.n_blocks
        });
        rep.push("artifact_open_eager(micro_l)", "micro_l", 1.0, s);
        let s = bench(warm_big, iters_big, || {
            ArtifactStore::open_lazy(&path).expect("lazy open").manifest().n_blocks
        });
        rep.push("artifact_open_lazy(micro_l)", "micro_l", 1.0, s);
        let zoo = rep.speedup(
            "zoo_cold_start",
            "artifact_open_eager(micro_l)",
            "artifact_open_lazy(micro_l)",
        );
        let _ = std::fs::remove_file(&path);
        zoo
    };

    // 7. Device models end-to-end (timing models, unchanged).
    let gpu = GpuModel::new(GpuConfig::xavier());
    let ops = vim_model_ops(&VimModel::base(), 1024);
    let s = bench(warm_big, iters_big, || gpu.run(&ops).total_seconds());
    report("gpu_model.e2e(base@1024)", &s);

    let acc = Accelerator::new(MambaXConfig::default());
    let scan_ops = vim_selective_ssm_ops(&VimModel::tiny(), 197);
    let s = bench(warm, iters, || acc.run(&scan_ops).total_cycles());
    report("sim.scan(tiny@224)", &s);

    rep.write("BENCH_hotpath.json").expect("persist bench record");
    if let (Some(scan_s), Some(fwd_s)) = (scan_speedup, fwd_speedup) {
        println!(
            "targets: scan {scan_s:.2}x (goal >= 2x), forward batch8 {fwd_s:.2}x (goal >= 1.5x)"
        );
    }
    if let Some(c) = calib_speedup {
        println!("calibrated batch8 forward vs dynamic: {c:.2}x (static scales, fused scan)");
    }
    if let Some(z) = zoo_speedup {
        println!("zoo cold start: lazy artifact open {z:.2}x vs eager (micro_l)");
    }
    println!("gate these records in CI with: mamba-x perfcheck (vs BENCH_baseline.json)");
}
