//! Hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//!
//!  * sim.scan_timing — the chunk-level cycle scheduler (the simulator's
//!    hot loop: one iteration per chunk-job);
//!  * quant.spe_scan_int — the bit-exact integer datapath;
//!  * sfu.eval — LUT evaluation;
//!  * batcher — coordinator enqueue/release;
//!  * gpu model — full-device workload evaluation.

use mamba_x::config::{GpuConfig, MambaXConfig, VimModel};
use mamba_x::coordinator::{BatchPolicy, DynamicBatcher};
use mamba_x::gpu::GpuModel;
use mamba_x::quant::spe_scan_int;
use mamba_x::sim::memory::Dram;
use mamba_x::sim::{scan_timing, Accelerator};
use mamba_x::util::bench::{bench, report};
use mamba_x::util::Pcg;
use mamba_x::vision::{vim_model_ops, vim_selective_ssm_ops};

fn main() {
    println!("=== hot-path microbenches ===");

    // 1. Cycle scheduler at the largest paper shape (base@1024).
    let m = VimModel::base();
    let (l, h, n) = (m.seq_len(1024), m.d_inner(), m.d_state);
    let cfg = MambaXConfig::default();
    let jobs = (h * n * l.div_ceil(cfg.chunk)) as f64;
    let s = bench(2, 10, || {
        let mut dram = Dram::new(cfg.dram_bytes_per_cycle());
        scan_timing(&cfg, &mut dram, l, h, n).cycles
    });
    report("scan_timing(base@1024)", &s);
    println!(
        "    -> {:.1} M chunk-jobs/s ({:.0} jobs/run)",
        jobs / s.mean_ns * 1e3,
        jobs
    );

    // 2. Integer SPE datapath.
    let (sl, sh, sn) = (512usize, 64, 16);
    let mut rng = Pcg::new(1);
    let total = sl * sh * sn;
    let p: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let q: Vec<i64> = (0..total).map(|_| rng.int8()).collect();
    let shift: Vec<i32> = (0..sh).map(|_| 7).collect();
    let s = bench(2, 20, || spe_scan_int(&p, &q, &shift, sl, sh, sn));
    report("spe_scan_int(512x64x16)", &s);
    println!(
        "    -> {:.1} M lane-steps/s",
        total as f64 / s.mean_ns * 1e3
    );

    // 3. SFU LUT evaluation (if artifacts exist).
    if let Ok(tables) = mamba_x::sim::sfu::SfuTables::load("artifacts/sfu_luts.json") {
        let xs: Vec<f32> = (0..65536).map(|i| -8.0 + 16.0 * (i as f32 / 65536.0)).collect();
        let s = bench(2, 50, || {
            let mut acc = 0.0f32;
            for &x in &xs {
                acc += tables.silu.eval(x);
            }
            acc
        });
        report("sfu.silu_lut(64k evals)", &s);
        println!("    -> {:.1} M evals/s", 65536.0 / s.mean_ns * 1e3);
    } else {
        println!("(skipping sfu bench: run `make artifacts`)");
    }

    // 4. Batcher throughput.
    let s = bench(2, 50, || {
        let mut b = DynamicBatcher::new(BatchPolicy { max_batch: 8, max_wait_us: 100 });
        let mut out = 0usize;
        for i in 0..10_000u64 {
            b.push(i, i);
            if let Some(batch) = b.poll(i) {
                out += batch.len();
            }
        }
        out + b.flush().len()
    });
    report("batcher(10k reqs)", &s);

    // 5. Device models end-to-end.
    let gpu = GpuModel::new(GpuConfig::xavier());
    let ops = vim_model_ops(&VimModel::base(), 1024);
    let s = bench(2, 10, || gpu.run(&ops).total_seconds());
    report("gpu_model.e2e(base@1024)", &s);

    let acc = Accelerator::new(MambaXConfig::default());
    let scan_ops = vim_selective_ssm_ops(&VimModel::tiny(), 197);
    let s = bench(2, 50, || acc.run(&scan_ops).total_cycles());
    report("sim.scan(tiny@224)", &s);
}
