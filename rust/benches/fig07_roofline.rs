//! Paper Fig 7: roofline analysis on the Jetson AGX Xavier — selective
//! SSM sits at low operational intensity AND low achieved performance;
//! GEMM sits near the compute roof.

use mamba_x::config::{GpuConfig, VimModel, IMAGE_SIZES};
use mamba_x::gpu::roofline_point;
use mamba_x::vision::Op;

fn main() {
    println!("=== Fig 7: roofline (Xavier) ===");
    let gpu = GpuConfig::xavier();
    println!(
        "{:>7} {:>5} {:>10} {:>12} {:>9} {:>10} {:>12} {:>9}",
        "model", "img", "scan I", "scan GFLOPS", "scan %pk", "gemm I", "gemm GFLOPS", "gemm %pk"
    );
    for name in VimModel::ALL {
        let m = VimModel::by_name(name).unwrap();
        for img in IMAGE_SIZES {
            let l = m.seq_len(img);
            let scan = roofline_point(
                &gpu,
                &m,
                img,
                &Op::SelectiveSsm { l, h: m.d_inner(), n_state: m.d_state },
            );
            let gemm = roofline_point(
                &gpu,
                &m,
                img,
                &Op::Gemm { m: l, n: 2 * m.d_inner(), k: m.d_model },
            );
            println!(
                "{:>7} {:>5} {:>10.1} {:>12.1} {:>8.1}% {:>10.1} {:>12.1} {:>8.1}%",
                name,
                img,
                scan.intensity,
                scan.achieved_flops / 1e9,
                scan.peak_fraction * 100.0,
                gemm.intensity,
                gemm.achieved_flops / 1e9,
                gemm.peak_fraction * 100.0
            );
            // Paper Fig 7's qualitative claims.
            assert!(scan.intensity < gemm.intensity);
            assert!(scan.achieved_flops < gemm.achieved_flops);
            assert!(scan.peak_fraction < 0.30, "scan far from peak");
        }
    }
    println!(
        "(roofs: CUDA fp32 {:.2} TFLOPS, tensor {:.1} TFLOPS, {:.1} GB/s)",
        gpu.fp32_flops() / 1e12,
        gpu.tensor_tflops,
        gpu.dram_bw_gbs
    );
}
