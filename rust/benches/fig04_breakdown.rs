//! Paper Fig 4: latency breakdown of the Vim encoder on the edge GPU.
//! Expected shape: selective SSM dominates (up to ~60%+) for >=512 px,
//! GEMM share grows with model size.

use mamba_x::config::{GpuConfig, VimModel, IMAGE_SIZES};
use mamba_x::gpu::GpuModel;
use mamba_x::util::bench::{bench, report};
use mamba_x::vision::{vim_model_ops, OpClass};

fn main() {
    println!("=== Fig 4: Vim encoder latency breakdown on edge GPU ===");
    let gpu = GpuModel::new(GpuConfig::xavier());
    println!(
        "{:>7} {:>5} {:>7} {:>9} {:>7} {:>9} {:>12}",
        "model", "img", "GEMM", "LayerNorm", "Conv1D", "Elemwise", "SelectiveSSM"
    );
    for name in VimModel::ALL {
        let m = VimModel::by_name(name).unwrap();
        for img in IMAGE_SIZES {
            let r = gpu.run(&vim_model_ops(&m, img));
            let t = r.total_seconds();
            let pct = |c| 100.0 * r.seconds(c) / t;
            println!(
                "{:>7} {:>5} {:>6.1}% {:>8.1}% {:>6.1}% {:>8.1}% {:>11.1}%",
                name,
                img,
                pct(OpClass::Gemm),
                pct(OpClass::LayerNorm),
                pct(OpClass::Conv1d),
                pct(OpClass::Elementwise),
                pct(OpClass::SelectiveSsm)
            );
            if img >= 512 {
                assert!(
                    pct(OpClass::SelectiveSsm) > 40.0,
                    "scan must dominate at {img} (paper: up to 60%)"
                );
            }
        }
    }
    let m = VimModel::base();
    let s = bench(2, 20, || gpu.run(&vim_model_ops(&m, 738)).total_seconds());
    report("gpu_model(vim_base@738)", &s);
}
