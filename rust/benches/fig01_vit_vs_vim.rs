//! Paper Fig 1: ViT vs Vision Mamba end-to-end latency and memory on the
//! edge GPU, swept over input image size. Expected shape: ViT's latency
//! and memory blow up superlinearly (L² attention + score matrix); Vim
//! stays near-linear, with the gap widening as resolution grows.

use mamba_x::config::{GpuConfig, VimModel, VitModel};
use mamba_x::gpu::GpuModel;
use mamba_x::util::bench::{bench, report};
use mamba_x::vision::{vim_model_ops, vit_model_ops, vit_score_matrix_bytes};

fn main() {
    println!("=== Fig 1: ViT vs Vision Mamba (edge GPU model) ===");
    let gpu = GpuModel::new(GpuConfig::xavier());
    let vim = VimModel::tiny();
    let vit = VitModel::tiny();

    println!(
        "{:>6} {:>11} {:>11} {:>9} {:>11} {:>11}",
        "img", "ViT ms", "Vim ms", "ViT/Vim", "ViT MB", "Vim MB"
    );
    let mut last_ratio = 0.0;
    for img in [224usize, 448, 672, 896, 1024] {
        let tv = gpu.run(&vit_model_ops(&vit, img)).total_seconds() * 1e3;
        let tm = gpu.run(&vim_model_ops(&vim, img)).total_seconds() * 1e3;
        let mv = (vit.param_count() as f64 * 2.0
            + vit_score_matrix_bytes(&vit, img, 2.0)
            + vit.seq_len(img) as f64 * vit.d_model as f64 * 8.0)
            / 1e6;
        let mm = (vim.param_count() as f64 * 2.0
            + vim.seq_len(img) as f64 * vim.d_inner() as f64 * 16.0)
            / 1e6;
        let ratio = tv / tm;
        println!(
            "{:>6} {:>11.2} {:>11.2} {:>8.2}x {:>11.1} {:>11.1}",
            img, tv, tm, ratio, mv, mm
        );
        // Paper Fig 1: Vim's advantage grows with image size.
        assert!(ratio >= last_ratio * 0.95, "advantage must grow with size");
        last_ratio = ratio;
    }

    // Timing: the device-model evaluation itself (sim throughput).
    let s = bench(2, 20, || gpu.run(&vim_model_ops(&vim, 1024)).total_seconds());
    report("gpu_model(vim_tiny@1024)", &s);
}
