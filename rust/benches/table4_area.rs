//! Paper Table 4: Mamba-X area breakdown at 32 nm and 12 nm, plus the
//! §6.2 headline: Mamba-X uses ~0.4% of the Xavier die and delivers
//! ~601x performance/area on the end-to-end workload.

use mamba_x::config::{GpuConfig, MambaXConfig, VimModel, IMAGE_SIZES};
use mamba_x::energy::{AreaModel, TechNode};
use mamba_x::gpu::GpuModel;
use mamba_x::sim::Accelerator;
use mamba_x::vision::vim_model_ops;

fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

fn main() {
    println!("=== Table 4: area breakdown (mm^2) ===");
    let cfg = MambaXConfig::default();
    println!(
        "{:>6} {:>6} {:>6} {:>6} {:>6} {:>6} {:>7} {:>7} {:>7}",
        "node", "SSA", "SFU", "VPU", "PPU", "GEMM", "Buffer", "Others", "Total"
    );
    let paper32 = [0.28, 1.00, 0.23, 0.85, 5.34, 1.74, 0.04, 9.48];
    let a32 = AreaModel::mamba_x(&cfg);
    for node in [TechNode::N32, TechNode::N12] {
        let a = a32.at(node);
        println!(
            "{:>6} {:>6.2} {:>6.2} {:>6.2} {:>6.2} {:>6.2} {:>7.2} {:>7.2} {:>7.2}",
            format!("{node:?}"),
            a.ssa,
            a.sfu,
            a.vpu,
            a.ppu,
            a.gemm,
            a.buffer,
            a.others,
            a.total()
        );
    }
    println!(
        "paper32 {:>5.2} {:>6.2} {:>6.2} {:>6.2} {:>6.2} {:>7.2} {:>7.2} {:>7.2}",
        paper32[0], paper32[1], paper32[2], paper32[3], paper32[4], paper32[5], paper32[6], paper32[7]
    );
    let got = [a32.ssa, a32.sfu, a32.vpu, a32.ppu, a32.gemm, a32.buffer, a32.others, a32.total()];
    for (g, w) in got.iter().zip(paper32.iter()) {
        assert!((g - w).abs() / w < 0.12, "area row off: got {g:.2}, paper {w}");
    }

    // §6.2 headline: perf/area vs the edge GPU.
    let a12 = a32.at(TechNode::N12).total();
    let die = GpuConfig::xavier().die_mm2;
    println!("\nMamba-X @12nm: {:.2} mm^2 = {:.2}% of Xavier die ({die} mm^2)", a12, 100.0 * a12 / die);
    let gpu = GpuModel::new(GpuConfig::xavier());
    let acc = Accelerator::new(cfg.clone());
    let mut ppa = Vec::new();
    for name in VimModel::ALL {
        let m = VimModel::by_name(name).unwrap();
        for img in IMAGE_SIZES {
            let ops = vim_model_ops(&m, img);
            let sp = gpu.run(&ops).total_seconds() / acc.run(&ops).seconds(&acc.cfg);
            ppa.push(sp * die / a12);
        }
    }
    println!(
        "perf/area vs edge GPU: geomean {:.0}x (paper: 601x)",
        geomean(&ppa)
    );
    assert!(geomean(&ppa) > 100.0, "perf/area advantage must be large");
}
