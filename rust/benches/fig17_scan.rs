//! Paper Fig 17: selective-SSM speedup (a), energy-efficiency (b) and
//! off-chip traffic reduction (c) of Mamba-X vs the edge GPU, swept over
//! #SSAs ({2,4,8}), image size and model. Expected shape: speedup grows
//! with #SSAs and image size; paper averages 11.6x speedup, ~2.5x traffic.

use mamba_x::config::{GpuConfig, MambaXConfig, VimModel, IMAGE_SIZES, SSA_SWEEP};
use mamba_x::gpu::GpuModel;
use mamba_x::sim::Accelerator;
use mamba_x::util::bench::{bench, report};
use mamba_x::vision::vim_selective_ssm_ops;

fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

fn main() {
    println!("=== Fig 17: selective-SSM — Mamba-X vs edge GPU ===");
    let gpu = GpuModel::new(GpuConfig::xavier());
    println!(
        "{:>7} {:>5} {:>6} {:>9} {:>11} {:>10}",
        "model", "img", "SSAs", "speedup", "energy-eff", "traffic-x"
    );
    let mut sp8 = Vec::new();
    let mut ee8 = Vec::new();
    let mut tr8 = Vec::new();
    for name in VimModel::ALL {
        let m = VimModel::by_name(name).unwrap();
        for img in IMAGE_SIZES {
            let ops = vim_selective_ssm_ops(&m, m.seq_len(img));
            let rg = gpu.run(&ops);
            let mut prev_speedup = 0.0;
            for n_ssa in SSA_SWEEP {
                let acc = Accelerator::new(MambaXConfig::with_ssas(n_ssa));
                let ra = acc.run(&ops);
                let sp = rg.total_seconds() / ra.seconds(&acc.cfg);
                let ee = rg.energy_j / ra.energy_j;
                let tr = rg.total_bytes() / ra.total_bytes();
                println!(
                    "{:>7} {:>5} {:>6} {:>8.1}x {:>10.1}x {:>9.2}x",
                    name, img, n_ssa, sp, ee, tr
                );
                // Fig 17(a): scalable with SSA count.
                assert!(sp >= prev_speedup, "speedup must scale with SSAs");
                prev_speedup = sp;
                if n_ssa == 8 {
                    sp8.push(sp);
                    ee8.push(ee);
                    tr8.push(tr);
                    assert!(sp > 1.0, "Mamba-X must beat the GPU on the scan");
                    assert!(tr > 1.0, "traffic must shrink (paper: 2.5x avg)");
                }
            }
        }
    }
    println!(
        "\ngeomean @8 SSAs: speedup {:.1}x (paper 11.6x), energy-eff {:.1}x, traffic {:.2}x (paper 2.5x)",
        geomean(&sp8),
        geomean(&ee8),
        geomean(&tr8)
    );

    // Simulator hot-path timing: the chunk-level cycle scheduler.
    let m = VimModel::base();
    let ops = vim_selective_ssm_ops(&m, m.seq_len(1024));
    let acc = Accelerator::new(MambaXConfig::default());
    let s = bench(2, 10, || acc.run(&ops).total_cycles());
    report("sim.scan_timing(base@1024)", &s);
}
