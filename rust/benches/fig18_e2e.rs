//! Paper Fig 18: end-to-end latency breakdown (a) and energy-efficiency
//! (b) of Mamba-X vs the edge GPU. Expected shape: large scan-latency
//! reduction, GEMM comparable, overall ~2-3x e2e speedup that *shrinks*
//! with model size (GEMM-dominated), energy-efficiency ~order 10x.

use mamba_x::config::{GpuConfig, MambaXConfig, VimModel, IMAGE_SIZES};
use mamba_x::gpu::GpuModel;
use mamba_x::sim::Accelerator;
use mamba_x::util::bench::{bench, report};
use mamba_x::vision::{vim_model_ops, OpClass};

fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

fn main() {
    println!("=== Fig 18: end-to-end — Mamba-X vs edge GPU ===");
    let gpu = GpuModel::new(GpuConfig::xavier());
    let acc = Accelerator::new(MambaXConfig::default());
    println!(
        "{:>7} {:>5} {:>10} {:>12} {:>11} {:>13} {:>9} {:>11}",
        "model", "img", "gpu ms", "gpu scan %", "mamba-x ms", "mx scan %", "speedup", "energy-eff"
    );
    let mut sp_all = Vec::new();
    let mut ee_all = Vec::new();
    let mut per_model_sp = Vec::new();
    for name in VimModel::ALL {
        let m = VimModel::by_name(name).unwrap();
        let mut model_sp = Vec::new();
        for img in IMAGE_SIZES {
            let ops = vim_model_ops(&m, img);
            let rg = gpu.run(&ops);
            let ra = acc.run(&ops);
            let t_g = rg.total_seconds();
            let t_a = ra.seconds(&acc.cfg);
            let scan_g = 100.0 * rg.seconds(OpClass::SelectiveSsm) / t_g;
            let scan_a = 100.0 * ra.cycles(OpClass::SelectiveSsm) as f64
                / ra.total_cycles() as f64;
            let sp = t_g / t_a;
            let ee = rg.energy_j / ra.energy_j;
            println!(
                "{:>7} {:>5} {:>10.2} {:>11.1}% {:>11.2} {:>12.1}% {:>8.2}x {:>10.1}x",
                name,
                img,
                t_g * 1e3,
                scan_g,
                t_a * 1e3,
                scan_a,
                sp,
                ee
            );
            assert!(sp > 1.0, "Mamba-X must win e2e");
            // Fig 18(a): the scan's latency share collapses on Mamba-X.
            assert!(scan_a < scan_g, "scan share must shrink on Mamba-X");
            sp_all.push(sp);
            ee_all.push(ee);
            model_sp.push(sp);
        }
        per_model_sp.push((name, geomean(&model_sp)));
    }
    println!(
        "\ngeomean e2e speedup {:.2}x (paper 2.3x); energy-eff {:.1}x (paper 11.5x)",
        geomean(&sp_all),
        geomean(&ee_all)
    );
    for (name, sp) in &per_model_sp {
        println!("  {name}: {sp:.2}x");
    }
    // Fig 18: speedup diminishes as the model gets GEMM-bound.
    assert!(
        per_model_sp[0].1 >= per_model_sp[2].1 * 0.8,
        "tiny should benefit at least comparably to base"
    );

    let ops = vim_model_ops(&VimModel::base(), 1024);
    let s = bench(1, 5, || acc.run(&ops).total_cycles());
    report("sim.e2e(base@1024)", &s);
}
